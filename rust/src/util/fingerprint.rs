//! Canonical 64-bit content fingerprints over [`Json`] values.
//!
//! The service's result cache and batching stage key on *request
//! identity*: two requests with the same fingerprint must describe the
//! same computation. [`Json`] objects are `BTreeMap`s, so key order is
//! already canonical; the walk below adds a type tag per node plus
//! explicit lengths so distinct shapes can't collide by concatenation
//! (e.g. `["ab"]` vs `["a","b"]`), and normalizes `-0.0` to `0.0` so the
//! two JSON spellings of zero — which every numeric consumer in the crate
//! treats identically — share a key.
//!
//! This is FNV-1a + a splitmix64 avalanche, not a cryptographic hash: a
//! 64-bit collision between two *different* requests is possible in
//! principle but needs ~2^32 distinct live entries to become likely —
//! the cache holds a few hundred. Keys never leave the process.

use super::hash::Fnv1a;
use crate::testutil::json::Json;

const TAG_NULL: u8 = 0;
const TAG_FALSE: u8 = 1;
const TAG_TRUE: u8 = 2;
const TAG_NUM: u8 = 3;
const TAG_STR: u8 = 4;
const TAG_ARR: u8 = 5;
const TAG_OBJ: u8 = 6;

fn walk(v: &Json, h: &mut Fnv1a) {
    match v {
        Json::Null => h.write_u8(TAG_NULL),
        Json::Bool(false) => h.write_u8(TAG_FALSE),
        Json::Bool(true) => h.write_u8(TAG_TRUE),
        Json::Num(n) => {
            h.write_u8(TAG_NUM);
            let n = if *n == 0.0 { 0.0 } else { *n };
            h.write_u64(n.to_bits());
        }
        Json::Str(s) => {
            h.write_u8(TAG_STR);
            h.write_str(s);
        }
        Json::Arr(items) => {
            h.write_u8(TAG_ARR);
            h.write_u64(items.len() as u64);
            for item in items {
                walk(item, h);
            }
        }
        Json::Obj(map) => {
            h.write_u8(TAG_OBJ);
            h.write_u64(map.len() as u64);
            for (k, item) in map {
                h.write_str(k);
                walk(item, h);
            }
        }
    }
}

/// Canonical fingerprint of a full [`Json`] value.
pub fn fingerprint(v: &Json) -> u64 {
    let mut h = Fnv1a::new();
    walk(v, &mut h);
    h.finish()
}

/// Fingerprint of `v` with the named *top-level object keys* left out.
///
/// The service uses this to exclude fields that don't change the computed
/// mapping from the cache key (`"cache"`, `"profile"`), and to exclude the
/// per-request task set (`"tcoords"`, `"edges"`) from the batching
/// compatibility key. For non-object values the skip list is irrelevant
/// and this equals [`fingerprint`].
pub fn fingerprint_excluding(v: &Json, skip: &[&str]) -> u64 {
    let Json::Obj(map) = v else {
        return fingerprint(v);
    };
    let mut h = Fnv1a::new();
    h.write_u8(TAG_OBJ);
    let kept = map.iter().filter(|(k, _)| !skip.contains(&k.as_str()));
    h.write_u64(kept.clone().count() as u64);
    for (k, item) in kept {
        h.write_str(k);
        walk(item, &mut h);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::json::Json;

    fn parse(s: &str) -> Json {
        Json::parse(s).expect("test JSON parses")
    }

    #[test]
    fn equal_values_share_a_fingerprint_regardless_of_key_order() {
        let a = parse(r#"{"op":"map","tcoords":[[0,0],[1,0]],"torus":true}"#);
        let b = parse(r#"{"torus":true,"op":"map","tcoords":[[0,0],[1,0]]}"#);
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn distinct_shapes_do_not_collide_by_concatenation() {
        let pairs = [
            (r#"["ab"]"#, r#"["a","b"]"#),
            (r#"{"a":1,"b":2}"#, r#"{"a":1}"#),
            (r#"[1,2]"#, r#"[[1,2]]"#),
            (r#""1""#, r#"1"#),
            (r#"[0]"#, r#"[false]"#),
            (r#"null"#, r#"[]"#),
        ];
        for (x, y) in pairs {
            assert_ne!(
                fingerprint(&parse(x)),
                fingerprint(&parse(y)),
                "{x} vs {y}"
            );
        }
    }

    #[test]
    fn negative_zero_normalizes() {
        assert_eq!(
            fingerprint(&Json::Num(0.0)),
            fingerprint(&Json::Num(-0.0))
        );
        assert_ne!(fingerprint(&Json::Num(0.0)), fingerprint(&Json::Num(1.0)));
    }

    #[test]
    fn excluding_ignores_only_the_named_top_level_keys() {
        let a = parse(r#"{"op":"map","cache":false,"profile":true,"torus":true}"#);
        let b = parse(r#"{"op":"map","torus":true}"#);
        let skip = ["cache", "profile"];
        assert_eq!(
            fingerprint_excluding(&a, &skip),
            fingerprint_excluding(&b, &skip)
        );
        assert_eq!(fingerprint_excluding(&b, &skip), fingerprint(&b));
        // A *nested* "cache" key is data, not a control field.
        let c = parse(r#"{"op":"map","hier":{"cache":1},"torus":true}"#);
        let d = parse(r#"{"op":"map","hier":{},"torus":true}"#);
        assert_ne!(
            fingerprint_excluding(&c, &skip),
            fingerprint_excluding(&d, &skip)
        );
    }

    #[test]
    fn task_set_excluded_key_groups_compatible_requests() {
        let a = parse(r#"{"op":"map","tcoords":[[0,0]],"torus":true,"ordering":"hilbert"}"#);
        let b = parse(r#"{"op":"map","tcoords":[[1,1],[2,2]],"torus":true,"ordering":"hilbert"}"#);
        let c = parse(r#"{"op":"map","tcoords":[[0,0]],"torus":false,"ordering":"hilbert"}"#);
        let skip = ["tcoords", "edges", "cache", "profile"];
        assert_eq!(
            fingerprint_excluding(&a, &skip),
            fingerprint_excluding(&b, &skip)
        );
        assert_ne!(
            fingerprint_excluding(&a, &skip),
            fingerprint_excluding(&c, &skip)
        );
    }
}
