//! FNV-1a and splitmix64: the crate's two non-cryptographic mixing
//! primitives, shared by fault-injection decisions (`testutil::faults`),
//! RNG stream seeding (`testutil::rng`) and request fingerprinting
//! ([`super::fingerprint`]).
//!
//! The constants and round functions are the canonical published ones;
//! `testutil::faults::would_fire`'s decision sequence is a pure function of
//! them, so they must never change (chaos seeds pin exact fire counts).

/// 64-bit FNV-1a offset basis.
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;
/// 64-bit FNV-1a prime.
pub const FNV_PRIME: u64 = 0x100000001b3;
/// The golden-ratio increment used by splitmix64 (and to decorrelate
/// composite hash inputs).
pub const GOLDEN: u64 = 0x9E3779B97F4A7C15;

/// FNV-1a over a string's UTF-8 bytes.
pub fn fnv1a(s: &str) -> u64 {
    let mut h = Fnv1a::new();
    h.write(s.as_bytes());
    h.finish()
}

/// splitmix64 finalizer: one strong 64→64-bit mix (advances by [`GOLDEN`]
/// first, matching the published generator's output for state `z`).
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(GOLDEN);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Streaming FNV-1a hasher for composite keys (the fingerprint module
/// feeds type tags, lengths, and payload bytes through one of these).
#[derive(Clone, Debug)]
pub struct Fnv1a {
    state: u64,
}

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a { state: FNV_OFFSET }
    }

    pub fn write_u8(&mut self, b: u8) {
        self.state ^= u64::from(b);
        self.state = self.state.wrapping_mul(FNV_PRIME);
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for b in bytes {
            self.write_u8(*b);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    /// Finish with a splitmix64 avalanche so short inputs still spread
    /// over all 64 bits (plain FNV-1a is weak in the high bits).
    pub fn finish(&self) -> u64 {
        splitmix64(self.state)
    }

    /// Raw FNV-1a state without the final mix — what the historical
    /// `fnv1a(site)` helper returned; `would_fire` depends on this value.
    pub fn finish_raw(&self) -> u64 {
        self.state
    }
}

/// FNV-1a over a string *without* the final avalanche — byte-for-byte the
/// function `testutil::faults` always used for site names.
pub fn fnv1a_raw(s: &str) -> u64 {
    let mut h = Fnv1a::new();
    h.write(s.as_bytes());
    h.finish_raw()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64-bit vectors.
        assert_eq!(fnv1a_raw(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_raw("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_raw("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn splitmix64_matches_reference_sequence() {
        // First three outputs of the published splitmix64 generator
        // seeded with 1234567: state advances by GOLDEN each call, and
        // our finalizer form gives output k as splitmix64(seed + k*GOLDEN).
        let seed = 1234567u64;
        let expect = [
            0x599ed017fb08fc85u64,
            0x2c73f08458540fa5u64,
            0x883ebce5a3f27c77u64,
        ];
        for (k, e) in expect.iter().enumerate() {
            assert_eq!(splitmix64(seed.wrapping_add(GOLDEN * k as u64)), *e);
        }
    }

    #[test]
    fn streaming_hasher_matches_oneshot() {
        let mut h = Fnv1a::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish_raw(), fnv1a_raw("foobar"));
        assert_eq!(h.finish(), fnv1a("foobar"));
    }
}
