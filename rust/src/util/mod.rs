//! Small shared utilities with no dependencies on the rest of the crate.
//!
//! * [`hash`] — the FNV-1a / splitmix64 mixing primitives previously
//!   duplicated between `testutil::faults` and `testutil::rng`, now the
//!   single hash implementation for fault-decision seeding, RNG stream
//!   setup, and request fingerprinting.
//! * [`fingerprint`] — a canonical 64-bit content fingerprint over
//!   [`crate::testutil::json::Json`] values, used by the service's result
//!   cache and batching stage to key on full request identity.

pub mod fingerprint;
pub mod hash;
