//! Deterministic fork–join parallelism for the mapping hot path.
//!
//! The offline vendor set has no `rayon`, so this module provides the three
//! rayon-style primitives the partitioner and the rotation sweep need —
//! budgeted [`join`], a chunked [`map_with`] fan-out with per-worker scratch
//! state, and a consuming [`for_each_vec`] — built on `std::thread::scope`.
//!
//! # Threading model
//!
//! * A [`Parallelism`] value is an explicit *thread budget* carried down the
//!   call tree. [`join`] splits the budget between its two halves and only
//!   spawns while at least two threads remain, so a computation started with
//!   `Parallelism::threads(8)` never runs more than ~8 worker threads at
//!   once, no matter how deep the recursion — no global pool, no global
//!   state, no oversubscription when sweeps nest inside sweeps.
//! * `Parallelism::auto()` sizes the budget from the `TASKMAP_THREADS`
//!   environment variable when set, else `std::thread::available_parallelism`.
//! * The `grain` is the smallest sub-problem (in items/points) worth
//!   splitting; below it callers recurse sequentially. Tests shrink it to
//!   force splits on tiny inputs.
//!
//! # Determinism guarantee
//!
//! Every primitive here assigns work to workers by *index*, not by arrival
//! order, and writes results into pre-assigned slots. Combined with
//! deterministic sequential kernels this makes all parallel results
//! **bit-identical to the sequential path at every thread count** — the
//! property tests in `tests/properties.rs` pin this for `mj_partition`,
//! `mj_multisection`, and `rotation_sweep`.

pub mod deadline;

pub use deadline::{Deadline, DeadlineExceeded};

use std::marker::PhantomData;
use std::sync::OnceLock;

/// Default smallest sub-problem (points/items) worth splitting.
pub const DEFAULT_GRAIN: usize = 8192;

/// An explicit thread budget plus split granularity, passed down the call
/// tree (see the module docs for the model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
    grain: usize,
}

impl Parallelism {
    /// Single-threaded: the reference path all parallel results must match.
    pub fn sequential() -> Self {
        Parallelism {
            threads: 1,
            grain: DEFAULT_GRAIN,
        }
    }

    /// A budget of `n` worker threads (clamped to at least 1).
    pub fn threads(n: usize) -> Self {
        Parallelism {
            threads: n.max(1),
            grain: DEFAULT_GRAIN,
        }
    }

    /// Budget from `TASKMAP_THREADS` (if set) or the machine's available
    /// parallelism. The lookup is cached for the process lifetime.
    pub fn auto() -> Self {
        static AUTO: OnceLock<usize> = OnceLock::new();
        let n = *AUTO.get_or_init(|| {
            std::env::var("TASKMAP_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .filter(|&n| n >= 1)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1)
                })
        });
        Parallelism::threads(n)
    }

    /// Override the split granularity (tests use tiny grains to force
    /// parallel splits on small inputs).
    pub fn with_grain(mut self, grain: usize) -> Self {
        self.grain = grain.max(1);
        self
    }

    pub fn num_threads(&self) -> usize {
        self.threads
    }

    pub fn grain(&self) -> usize {
        self.grain
    }

    pub fn is_sequential(&self) -> bool {
        self.threads <= 1
    }

    /// Split the budget for the two sides of a `join` (left gets the larger
    /// half).
    pub fn split(&self) -> (Parallelism, Parallelism) {
        let left = self.threads.div_ceil(2);
        let right = (self.threads - left).max(1);
        (
            Parallelism {
                threads: left,
                grain: self.grain,
            },
            Parallelism {
                threads: right,
                grain: self.grain,
            },
        )
    }
}

/// Run `a` and `b`, possibly concurrently, handing each its share of the
/// budget. With fewer than two threads both run sequentially on the caller's
/// thread. Results are returned in `(a, b)` order regardless of scheduling.
pub fn join<RA, RB, A, B>(par: Parallelism, a: A, b: B) -> (RA, RB)
where
    RA: Send,
    RB: Send,
    A: FnOnce(Parallelism) -> RA + Send,
    B: FnOnce(Parallelism) -> RB + Send,
{
    if par.threads < 2 {
        let seq = Parallelism::sequential().with_grain(par.grain);
        return (a(seq), b(seq));
    }
    let (pa, pb) = par.split();
    std::thread::scope(|scope| {
        let hb = scope.spawn(move || b(pb));
        let ra = a(pa);
        let rb = match hb.join() {
            Ok(v) => v,
            Err(e) => std::panic::resume_unwind(e),
        };
        (ra, rb)
    })
}

/// Map `f` over `items` with up to `par.num_threads()` workers, giving every
/// worker its own scratch state from `init`. Items are assigned to workers
/// in contiguous index ranges and results land in input order, so the output
/// is identical at every thread count.
pub fn map_with<T, R, S, I, F>(par: Parallelism, items: &[T], init: I, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n = items.len();
    let workers = par.threads.min(n).max(1);
    if workers < 2 {
        let mut state = init();
        return items
            .iter()
            .enumerate()
            .map(|(i, t)| f(&mut state, i, t))
            .collect();
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let bounds: Vec<usize> = (0..=workers).map(|w| w * n / workers).collect();
    {
        // Pre-split the output into one disjoint chunk per worker.
        let mut chunks: Vec<&mut [Option<R>]> = Vec::with_capacity(workers);
        let mut rest: &mut [Option<R>] = &mut out;
        for w in 0..workers {
            let (chunk, tail) =
                std::mem::take(&mut rest).split_at_mut(bounds[w + 1] - bounds[w]);
            chunks.push(chunk);
            rest = tail;
        }
        std::thread::scope(|scope| {
            let f = &f;
            let init = &init;
            // Spawn workers 1.. first, then run worker 0 inline.
            for (w, chunk) in chunks.into_iter().enumerate().rev() {
                let lo = bounds[w];
                let run = move || {
                    let mut state = init();
                    for (k, slot) in chunk.iter_mut().enumerate() {
                        *slot = Some(f(&mut state, lo + k, &items[lo + k]));
                    }
                };
                if w == 0 {
                    run();
                } else {
                    scope.spawn(run);
                }
            }
        });
    }
    out.into_iter()
        .map(|r| r.expect("every slot filled by its worker"))
        .collect()
}

/// Stateless [`map_with`].
pub fn map<T, R, F>(par: Parallelism, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    map_with(par, items, || (), |_, i, t| f(i, t))
}

/// Consume `items`, running `f` on each with a share of the budget. Used
/// where items hold `&mut` borrows (e.g. disjoint index slices of one
/// partition buffer) that cannot be handed out through `&[T]`.
pub fn for_each_vec<T, F>(par: Parallelism, mut items: Vec<T>, f: &F)
where
    T: Send,
    F: Fn(Parallelism, T) + Sync,
{
    match items.len() {
        0 => {}
        1 => f(par, items.pop().unwrap()),
        _ if par.threads >= 2 => {
            let right = items.split_off(items.len() / 2);
            join(
                par,
                move |p| for_each_vec(p, items, f),
                move |p| for_each_vec(p, right, f),
            );
        }
        _ => {
            let seq = Parallelism::sequential().with_grain(par.grain);
            for item in items {
                f(seq, item);
            }
        }
    }
}

/// A raw view of a `&mut [T]` that can be shared across the two sides of a
/// fork–join split when the caller guarantees the sides touch **disjoint
/// index sets** (MJ's recursion owns exactly the point indices in its `idx`
/// sub-slice; see `mj::bisect`).
///
/// All access is `unsafe`: the caller, not the type system, upholds the
/// disjointness invariant. Bounds are checked in debug builds.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: the slice's element type moves between threads only by value, and
// the disjoint-index contract (documented above) prevents aliased access.
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        SharedSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read element `i`.
    ///
    /// # Safety
    /// No concurrent writer may target index `i`.
    #[inline]
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// Write element `i`.
    ///
    /// # Safety
    /// No concurrent reader or writer may target index `i`.
    #[inline]
    pub unsafe fn set(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_budget_conserved() {
        let p = Parallelism::threads(8);
        let (l, r) = p.split();
        assert_eq!(l.num_threads(), 4);
        assert_eq!(r.num_threads(), 4);
        let (l, r) = Parallelism::threads(3).split();
        assert_eq!((l.num_threads(), r.num_threads()), (2, 1));
        let (l, r) = Parallelism::threads(2).split();
        assert_eq!((l.num_threads(), r.num_threads()), (1, 1));
    }

    #[test]
    fn join_returns_in_order() {
        for threads in [1, 2, 8] {
            let (a, b) = join(Parallelism::threads(threads), |_| "left", |_| "right");
            assert_eq!((a, b), ("left", "right"));
        }
    }

    #[test]
    fn join_nests() {
        let (a, (b, c)) = join(
            Parallelism::threads(4),
            |p| join(p, |_| 1, |_| 2),
            |p| join(p, |_| 3, |_| 4),
        );
        assert_eq!((a, (b, c)), ((1, 2), (3, 4)));
    }

    #[test]
    fn map_matches_sequential_at_every_thread_count() {
        let items: Vec<u64> = (0..1000).collect();
        let seq = map(Parallelism::sequential(), &items, |i, &x| x * 3 + i as u64);
        for threads in [2, 3, 8, 64] {
            let par = map(Parallelism::threads(threads), &items, |i, &x| {
                x * 3 + i as u64
            });
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn map_with_reuses_worker_state() {
        // Each worker's scratch must be isolated: the per-worker counter
        // resets per worker but results stay index-addressed.
        let items: Vec<usize> = (0..100).collect();
        let out = map_with(
            Parallelism::threads(4),
            &items,
            || 0usize,
            |count, i, &x| {
                *count += 1;
                (i, x, *count >= 1)
            },
        );
        for (i, &(oi, ox, counted)) in out.iter().enumerate() {
            assert_eq!((oi, ox), (i, i));
            assert!(counted);
        }
    }

    #[test]
    fn map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(map(Parallelism::threads(8), &empty, |_, &x| x).is_empty());
        assert_eq!(map(Parallelism::threads(8), &[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn for_each_vec_visits_all() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let total = AtomicU64::new(0);
        let items: Vec<u64> = (1..=100).collect();
        for_each_vec(Parallelism::threads(8), items, &|_, x| {
            total.fetch_add(x, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 5050);
    }

    #[test]
    fn shared_slice_disjoint_writes() {
        let mut buf = vec![0u32; 64];
        {
            let shared = SharedSlice::new(&mut buf);
            let shared = &shared;
            let idx: Vec<usize> = (0..64).collect();
            let (left, right) = idx.split_at(32);
            join(
                Parallelism::threads(2),
                move |_| {
                    for &i in left {
                        unsafe { shared.set(i, i as u32) }
                    }
                },
                move |_| {
                    for &i in right {
                        unsafe { shared.set(i, i as u32 * 2) }
                    }
                },
            );
        }
        for i in 0..32 {
            assert_eq!(buf[i], i as u32);
        }
        for i in 32..64 {
            assert_eq!(buf[i], i as u32 * 2);
        }
    }

    #[test]
    fn auto_has_at_least_one_thread() {
        assert!(Parallelism::auto().num_threads() >= 1);
    }
}
