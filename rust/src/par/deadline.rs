//! Cooperative compute deadlines for long-running mapping work.
//!
//! The mapping pipelines (rotation sweep, `MinVolume` refinement, the
//! depth-3 socket split) can run for a long time on pathological inputs.
//! Threads cannot be killed safely, so cancellation is **cooperative**: a
//! [`Deadline`] is threaded down the call tree and checked at phase
//! boundaries — between the sweep, each refinement stage, and placement —
//! so an over-budget computation stops at the next boundary and reports
//! *which* phase ran out of time instead of pinning a worker forever.
//!
//! A `Deadline` is `Copy` and checking it is a single `Instant` comparison,
//! so sprinkling checks at phase boundaries costs nothing on the happy
//! path. [`Deadline::unlimited`] never expires — library callers that do
//! not care about budgets pass it and keep the exact pre-deadline behavior
//! (the budgeted entry points are additive, not a semantic change).

use std::time::{Duration, Instant};

/// A point in time after which budgeted work should stop at the next phase
/// boundary. `None` means "no deadline" (never expires).
#[derive(Clone, Copy, Debug, Default)]
pub struct Deadline {
    at: Option<Instant>,
}

impl Deadline {
    /// A deadline that never expires (the default).
    pub fn unlimited() -> Deadline {
        Deadline { at: None }
    }

    /// Expire `budget` from now.
    pub fn within(budget: Duration) -> Deadline {
        Deadline {
            at: Some(Instant::now() + budget),
        }
    }

    /// Expire at an explicit instant.
    pub fn at(instant: Instant) -> Deadline {
        Deadline { at: Some(instant) }
    }

    /// Has the deadline passed?
    pub fn expired(&self) -> bool {
        match self.at {
            Some(at) => Instant::now() >= at,
            None => false,
        }
    }

    /// Time left, or `None` for an unlimited deadline. Zero when expired.
    pub fn remaining(&self) -> Option<Duration> {
        self.at.map(|at| at.saturating_duration_since(Instant::now()))
    }

    /// Phase-boundary check: `Err` names the phase that ran out of budget.
    /// When the recorder is on, each check emits a `deadline.check` instant
    /// carrying the remaining margin (omitted for unlimited deadlines), so
    /// a trace shows how close each phase came to its budget.
    pub fn check(&self, phase: &'static str) -> Result<(), DeadlineExceeded> {
        if crate::obs::recording() {
            match self.remaining() {
                Some(left) => crate::obs::instant(
                    "deadline.check",
                    &[("margin_us", left.as_micros() as f64)],
                ),
                None => crate::obs::instant("deadline.check", &[]),
            }
        }
        if self.expired() {
            Err(DeadlineExceeded { phase })
        } else {
            Ok(())
        }
    }
}

/// A budgeted computation ran past its deadline; `phase` names the phase
/// boundary where the overrun was detected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeadlineExceeded {
    pub phase: &'static str,
}

impl std::fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "compute budget exhausted at phase \"{}\"", self.phase)
    }
}

impl std::error::Error for DeadlineExceeded {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_never_expires() {
        let d = Deadline::unlimited();
        assert!(!d.expired());
        assert!(d.check("any").is_ok());
        assert_eq!(d.remaining(), None);
    }

    #[test]
    fn zero_budget_expires_immediately() {
        let d = Deadline::within(Duration::ZERO);
        assert!(d.expired());
        let e = d.check("sweep").unwrap_err();
        assert_eq!(e.phase, "sweep");
        assert!(e.to_string().contains("sweep"));
        assert_eq!(d.remaining(), Some(Duration::ZERO));
    }

    #[test]
    fn generous_budget_does_not_expire() {
        let d = Deadline::within(Duration::from_secs(3600));
        assert!(!d.expired());
        assert!(d.check("sweep").is_ok());
        assert!(d.remaining().unwrap() > Duration::from_secs(3599));
    }
}
