//! Multi-Jagged (MJ) geometric partitioning (Section 4.1, Algorithm 2).
//!
//! MJ recursively partitions a coordinate set along one dimension at a
//! time. Used as the sequential kernel of the task-mapping algorithm
//! (Section 4.2): the partition runs over both the task coordinates and the
//! processor coordinates, and part numbers — assigned by a space-filling
//! ordering (Z, Gray, FZ, MFZ) — tie the two together.
//!
//! Two modes:
//! * [`mj_partition`] — recursive (possibly uneven) **bisection** with the
//!   SFC part numbering of Algorithm 2. This is the mapping path.
//! * [`mj_multisection`] — the general multisection form with an explicit
//!   per-level part-count vector (`P = Π P_i`, Fig. 1), Z numbering.
//!
//! Cuts are found by exact selection (`select_nth_unstable`) on
//! (coordinate, index) keys: deterministic, tie-stable, O(n) per level.
//! Points with identical coordinates are only separated when a cut lands
//! inside their run, and subtree part numbers are contiguous — so e.g. the
//! ranks of one multicore node (identical router coordinates) always
//! receive a contiguous range of part numbers.
//!
//! # Threading model and scratch reuse
//!
//! The recursion is a fork–join over disjoint index sets: after each cut the
//! two sides own disjoint point indices, so they partition concurrently via
//! [`crate::par::join`] once a region holds at least `par.grain()` points
//! and the thread budget allows. Every sub-problem is deterministic and the
//! sides are data-disjoint, so **the parallel result is bit-identical to
//! the sequential one at every thread count** (pinned by property tests).
//!
//! Steady-state callers (the rotation sweep maps up to 36 candidates per
//! request) avoid per-call allocation with an [`MjScratch`] arena holding
//! the working axis copies and the index permutation. The contract:
//! a scratch may be reused across any sequence of `*_into` calls (they
//! resize and overwrite it), but must not be shared between concurrent
//! calls — use one scratch per worker (see `par::map_with`).

pub mod multisection;

pub use multisection::{mj_multisection, mj_multisection_into, mj_multisection_par};

use crate::geom::Coords;
use crate::par::{self, Parallelism, SharedSlice};
use crate::sfc::PartOrdering;

/// MJ configuration for the bisection/mapping path.
#[derive(Clone, Copy, Debug)]
pub struct MjConfig {
    /// Part-numbering ordering (Algorithm 2). `Hilbert` is not an MJ flip
    /// rule and is rejected here (handled by the mapping layer).
    pub ordering: PartOrdering,
    /// Cut perpendicular to the longest dimension of the current region
    /// (Section 4.3) instead of strictly alternating dimensions.
    pub longest_dim: bool,
    /// Uneven bisection by the largest prime divisor of the part count
    /// (the Z2_2/Z2_3 optimization of Section 5.3.1): splitting 10,800
    /// parts as 6,480 + 4,320 instead of 5,400 + 5,400 keeps nodes intact
    /// deeper into the hierarchy.
    pub uneven_prime: bool,
}

impl Default for MjConfig {
    fn default() -> Self {
        MjConfig {
            ordering: PartOrdering::FZ,
            longest_dim: true,
            uneven_prime: false,
        }
    }
}

/// Reusable working buffers for [`mj_partition_into`]: the mutable per-axis
/// coordinate copies (MJ's orderings flip coordinates in place, Alg. 2) and
/// the point-index permutation. Reuse across calls to keep the hot path
/// allocation-free; never share one scratch between concurrent calls.
#[derive(Default)]
pub struct MjScratch {
    axes: Vec<Vec<f64>>,
    idx: Vec<u32>,
}

impl MjScratch {
    pub fn new() -> Self {
        MjScratch::default()
    }
}

/// Partition `coords` into `num_parts` parts; returns the part id of every
/// point. Part sizes are balanced: `n mod num_parts` low-numbered parts get
/// one extra point. Runs with the auto thread budget
/// ([`Parallelism::auto`]); the result does not depend on the budget.
pub fn mj_partition(coords: &Coords, num_parts: usize, cfg: &MjConfig) -> Vec<u32> {
    mj_partition_par(coords, num_parts, cfg, Parallelism::auto())
}

/// [`mj_partition`] with an explicit thread budget.
pub fn mj_partition_par(
    coords: &Coords,
    num_parts: usize,
    cfg: &MjConfig,
    par: Parallelism,
) -> Vec<u32> {
    let mut scratch = MjScratch::new();
    let mut part = Vec::new();
    mj_partition_into(coords, num_parts, cfg, par, &mut scratch, &mut part);
    part
}

/// Zero-allocation (in steady state) form: writes part ids into `part`,
/// reusing `scratch` for the working axes and index permutation.
pub fn mj_partition_into(
    coords: &Coords,
    num_parts: usize,
    cfg: &MjConfig,
    par: Parallelism,
    scratch: &mut MjScratch,
    part: &mut Vec<u32>,
) {
    let ident: Vec<usize> = (0..coords.dim()).collect();
    mj_partition_axes_into(coords, &ident, num_parts, cfg, par, scratch, part);
}

/// Like [`mj_partition_into`], but partitions the coordinates viewed through
/// an axis permutation (working axis `d` reads `coords.axis(perm[d])`)
/// without materializing the permuted `Coords`. This is the rotation
/// sweep's zero-copy path: equivalent to
/// `mj_partition(&coords.permute_axes(perm), ..)`.
pub fn mj_partition_axes_into(
    coords: &Coords,
    perm: &[usize],
    num_parts: usize,
    cfg: &MjConfig,
    par: Parallelism,
    scratch: &mut MjScratch,
    part: &mut Vec<u32>,
) {
    assert!(num_parts >= 1);
    assert!(
        cfg.ordering != PartOrdering::Hilbert,
        "Hilbert is not an MJ part numbering; use mapping::hilbert_mapping"
    );
    let n = coords.len();
    assert!(
        num_parts <= n,
        "cannot make {num_parts} nonempty parts from {n} points"
    );
    let dim = coords.dim();
    assert_eq!(perm.len(), dim, "axis permutation length != dim");
    // Fill the scratch: working axis copies (flipped in place by the
    // orderings) in permuted order, the identity index permutation, and the
    // zeroed output.
    scratch.axes.resize_with(dim, Vec::new);
    for (d, axis) in scratch.axes.iter_mut().enumerate() {
        axis.clear();
        axis.extend_from_slice(coords.axis(perm[d]));
    }
    scratch.idx.clear();
    scratch.idx.extend(0..n as u32);
    part.clear();
    part.resize(n, 0);

    let MjScratch { axes, idx } = scratch;
    let shared = Shared {
        axes: axes
            .iter_mut()
            .map(|a| SharedSlice::new(a.as_mut_slice()))
            .collect(),
        part: SharedSlice::new(part.as_mut_slice()),
        base: n / num_parts,
        extra: n % num_parts,
        cfg: *cfg,
        dim,
    };
    bisect(&shared, idx, 0, num_parts, 0, par);

    // Observability: one instant per partition call (on the calling
    // thread's lane), carrying the recursion depth and the part-size
    // imbalance — both derived from the deterministic split rule, never
    // from timing, so traces replay bit-identically.
    if crate::obs::recording() {
        let max_part = shared.base + usize::from(shared.extra > 0);
        let mean_part = n as f64 / num_parts as f64;
        crate::obs::instant(
            "mj.partition",
            &[
                ("parts", num_parts as f64),
                ("points", n as f64),
                ("depth", recursion_depth(num_parts, cfg.uneven_prime) as f64),
                ("imbalance", max_part as f64 / mean_part),
            ],
        );
    }
}

/// Depth of the bisection recursion for `np` parts under the configured
/// split rule (1 part = depth 0). Mirrors [`split_parts`] exactly.
pub fn recursion_depth(np: usize, uneven_prime: bool) -> usize {
    if np <= 1 {
        return 0;
    }
    let (np_l, np_r) = split_parts(np, uneven_prime);
    1 + recursion_depth(np_l, uneven_prime).max(recursion_depth(np_r, uneven_prime))
}

/// Buffers shared across the two sides of a recursion split. Safety: every
/// `bisect` call owns exactly the point indices in its `idx` sub-slice, the
/// two sides of a split receive disjoint `idx` halves, and all axis/part
/// accesses are indexed by owned point indices only — so concurrent
/// accesses never alias.
struct Shared<'a> {
    axes: Vec<SharedSlice<'a, f64>>,
    part: SharedSlice<'a, u32>,
    /// Global part-size rule: part `p` holds `base + (p < extra)` points.
    base: usize,
    extra: usize,
    cfg: MjConfig,
    dim: usize,
}

/// Number of points owned by parts `[offset, offset + np)`.
fn span_count(sh: &Shared, offset: usize, np: usize) -> usize {
    let extra_here = sh.extra.saturating_sub(offset).min(np);
    np * sh.base + extra_here
}

/// Largest prime factor (num_parts in this codebase is at most ~2^21, so
/// trial division is instantaneous).
pub fn largest_prime_factor(mut n: usize) -> usize {
    let mut largest = 1;
    let mut f = 2;
    while f * f <= n {
        while n % f == 0 {
            largest = f;
            n /= f;
        }
        f += 1;
    }
    if n > 1 {
        largest = n;
    }
    largest
}

/// How to split `np` parts between the two sides of a bisection.
fn split_parts(np: usize, uneven_prime: bool) -> (usize, usize) {
    if uneven_prime {
        let p = largest_prime_factor(np);
        let np_l = np / p * p.div_ceil(2);
        (np_l, np - np_l)
    } else {
        (np.div_ceil(2), np / 2)
    }
}

fn bisect(sh: &Shared, idx: &mut [u32], offset: usize, np: usize, level: usize, par: Parallelism) {
    if np == 1 {
        for &i in idx.iter() {
            // SAFETY: this call owns point index `i` (it is in our `idx`).
            unsafe { sh.part.set(i as usize, offset as u32) };
        }
        return;
    }
    // Dimension to cut.
    let d = if sh.cfg.longest_dim {
        longest_dim_of(sh, idx)
    } else {
        level % sh.dim
    };
    let (np_l, np_r) = split_parts(np, sh.cfg.uneven_prime);
    let count_l = span_count(sh, offset, np_l);
    debug_assert!(count_l >= 1 && count_l < idx.len() + 1);
    // Exact selection on (coordinate, point index): deterministic ties.
    {
        let axis = &sh.axes[d];
        idx.select_nth_unstable_by(count_l - 1, |&a, &b| {
            // SAFETY: `a` and `b` are owned point indices.
            let (ca, cb) = unsafe { (axis.get(a as usize), axis.get(b as usize)) };
            ca.partial_cmp(&cb).unwrap().then(a.cmp(&b))
        });
    }
    let (left, right) = idx.split_at_mut(count_l);
    // Algorithm 2 flip rules.
    match sh.cfg.ordering {
        PartOrdering::Z => {}
        PartOrdering::Gray => {
            for &i in right.iter() {
                for axis in sh.axes.iter() {
                    // SAFETY: `i` is owned by this call.
                    unsafe { axis.set(i as usize, -axis.get(i as usize)) };
                }
            }
        }
        PartOrdering::FZ => {
            let axis = &sh.axes[d];
            for &i in right.iter() {
                // SAFETY: `i` is owned by this call.
                unsafe { axis.set(i as usize, -axis.get(i as usize)) };
            }
        }
        PartOrdering::MFZ => {
            // MFZ flips the LOWER half instead (Section 4.3).
            let axis = &sh.axes[d];
            for &i in left.iter() {
                // SAFETY: `i` is owned by this call.
                unsafe { axis.set(i as usize, -axis.get(i as usize)) };
            }
        }
        PartOrdering::Hilbert => unreachable!(),
    }
    // Fork–join split: both sides own disjoint point-index sets, so they
    // may run concurrently; below the grain (or out of budget) recurse
    // sequentially. Either way the result is identical.
    if par.num_threads() >= 2 && left.len().min(right.len()) >= par.grain() {
        par::join(
            par,
            move |p| bisect(sh, left, offset, np_l, level + 1, p),
            move |p| bisect(sh, right, offset + np_l, np_r, level + 1, p),
        );
    } else {
        bisect(sh, left, offset, np_l, level + 1, par);
        bisect(sh, right, offset + np_l, np_r, level + 1, par);
    }
}

fn longest_dim_of(sh: &Shared, idx: &[u32]) -> usize {
    let mut best = 0usize;
    let mut best_ext = f64::NEG_INFINITY;
    for (d, axis) in sh.axes.iter().enumerate() {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &i in idx {
            // SAFETY: `i` is owned by the calling `bisect`.
            let v = unsafe { axis.get(i as usize) };
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        let ext = hi - lo;
        if ext > best_ext {
            best_ext = ext;
            best = d;
        }
    }
    best
}

/// Part sizes produced by [`mj_partition`] for `n` points into `np` parts.
pub fn part_sizes(n: usize, np: usize) -> Vec<usize> {
    let base = n / np;
    let extra = n % np;
    (0..np).map(|p| base + usize::from(p < extra)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;

    fn grid(nx: usize, ny: usize) -> Coords {
        stencil_graph(&[nx, ny], false, 1.0).coords
    }

    fn counts(parts: &[u32], np: usize) -> Vec<usize> {
        let mut c = vec![0usize; np];
        for &p in parts {
            c[p as usize] += 1;
        }
        c
    }

    #[test]
    fn balanced_power_of_two() {
        let c = grid(8, 8);
        for ord in [PartOrdering::Z, PartOrdering::Gray, PartOrdering::FZ, PartOrdering::MFZ] {
            let cfg = MjConfig {
                ordering: ord,
                longest_dim: false,
                uneven_prime: false,
            };
            let parts = mj_partition(&c, 16, &cfg);
            assert_eq!(counts(&parts, 16), vec![4; 16], "{ord:?}");
        }
    }

    #[test]
    fn balanced_non_power_of_two() {
        let c = grid(10, 10);
        let parts = mj_partition(&c, 7, &MjConfig::default());
        let sizes = counts(&parts, 7);
        assert_eq!(sizes, part_sizes(100, 7));
        assert_eq!(sizes.iter().sum::<usize>(), 100);
    }

    #[test]
    fn one_part_trivial() {
        let c = grid(4, 4);
        let parts = mj_partition(&c, 1, &MjConfig::default());
        assert!(parts.iter().all(|&p| p == 0));
    }

    #[test]
    fn one_point_per_part() {
        let c = grid(4, 4);
        let parts = mj_partition(&c, 16, &MjConfig::default());
        let mut s: Vec<u32> = parts.clone();
        s.sort_unstable();
        assert_eq!(s, (0..16).collect::<Vec<u32>>());
    }

    #[test]
    fn z_order_on_square_grid_matches_morton() {
        // 4x4 grid into 16 parts, alternating dims starting with x, Z
        // ordering: part number = Morton(y, x) with x cut first.
        let c = grid(4, 4);
        let cfg = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: false,
            uneven_prime: false,
        };
        let parts = mj_partition(&c, 16, &cfg);
        for y in 0..4usize {
            for x in 0..4usize {
                let i = y * 4 + x;
                // First cut on x (bit 3), then y (bit 2), then x (bit 1),
                // then y (bit 0).
                let expect = ((x >> 1) << 3) | ((y >> 1) << 2) | ((x & 1) << 1) | (y & 1);
                assert_eq!(parts[i] as usize, expect, "({x},{y})");
            }
        }
    }

    #[test]
    fn parts_are_spatially_contiguous_z() {
        // Each part of an 8x8 grid into 4 parts must be a 4x4 quadrant.
        let c = grid(8, 8);
        let cfg = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: false,
            uneven_prime: false,
        };
        let parts = mj_partition(&c, 4, &cfg);
        for y in 0..8usize {
            for x in 0..8usize {
                let expect = (x / 4) * 2 + y / 4;
                assert_eq!(parts[y * 8 + x] as usize, expect, "({x},{y})");
            }
        }
    }

    #[test]
    fn fz_differs_from_z_in_numbering_not_membership() {
        // FZ flips coordinates, which changes which *numbers* parts get but
        // (for one level) not the cut membership.
        let c = grid(8, 8);
        let mk = |ordering| MjConfig {
            ordering,
            longest_dim: false,
            uneven_prime: false,
        };
        let z = mj_partition(&c, 64, &mk(PartOrdering::Z));
        let fz = mj_partition(&c, 64, &mk(PartOrdering::FZ));
        assert_ne!(z, fz);
        // Same multiset of sizes.
        assert_eq!(counts(&z, 64), counts(&fz, 64));
    }

    #[test]
    fn uneven_prime_split() {
        assert_eq!(split_parts(10800, true), (6480, 4320)); // paper's example
        assert_eq!(split_parts(8, true), (4, 4));
        assert_eq!(split_parts(6, true), (4, 2)); // p=3: 2/3 | 1/3
        assert_eq!(split_parts(7, true), (4, 3));
        assert_eq!(split_parts(10800, false), (5400, 5400));
    }

    #[test]
    fn largest_prime_factor_basic() {
        assert_eq!(largest_prime_factor(10800), 5);
        assert_eq!(largest_prime_factor(8), 2);
        assert_eq!(largest_prime_factor(7), 7);
        assert_eq!(largest_prime_factor(1), 1);
        assert_eq!(largest_prime_factor(97 * 4), 97);
    }

    #[test]
    fn identical_points_get_contiguous_parts() {
        // 4 ranks per "node" with identical coordinates: each node's ranks
        // must occupy a contiguous part-number range.
        let mut c = Coords::new(2);
        for node in 0..4 {
            for _ in 0..4 {
                c.push(&[(node % 2) as f64, (node / 2) as f64]);
            }
        }
        let parts = mj_partition(&c, 16, &MjConfig::default());
        for node in 0..4 {
            let mut ps: Vec<u32> = (0..4).map(|r| parts[node * 4 + r]).collect();
            ps.sort_unstable();
            for w in ps.windows(2) {
                assert_eq!(w[1], w[0] + 1, "node {node} parts not contiguous: {ps:?}");
            }
        }
    }

    #[test]
    fn longest_dim_cuts_the_long_axis_first() {
        // 16x2 grid into 2 parts: longest-dim must cut x, giving 8x2 halves.
        let c = grid(16, 2);
        let cfg = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: true,
            uneven_prime: false,
        };
        let parts = mj_partition(&c, 2, &cfg);
        for y in 0..2 {
            for x in 0..16 {
                let expect = u32::from(x >= 8);
                assert_eq!(parts[y * 16 + x], expect);
            }
        }
    }

    #[test]
    fn fig2_longest_dim() {
        // Fig. 2: on a 16x4 grid, three levels of longest-dimension
        // partitioning cut x, x, then x again (extent 16 -> 8 -> 4 = y-ext
        // tie broken toward x) — whereas strictly alternating cuts x, y, x.
        // The observable effect: with alternating cuts the 8 parts are
        // 4x2 blocks; with longest-dim they are 2x4 columns.
        let c = grid(16, 4);
        let alt = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: false,
            uneven_prime: false,
        };
        let lng = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: true,
            uneven_prime: false,
        };
        let pa = mj_partition(&c, 8, &alt);
        let pl = mj_partition(&c, 8, &lng);
        // Alternating: part of (x,y) constant on 4x2 blocks.
        assert_eq!(pa[0], pa[3 + 16]); // (0,0) and (3,1) same 4x2 block
        assert_ne!(pa[0], pa[2 * 16]); // (0,2) different y-half
        // Longest-dim: columns of width 2 spanning all y.
        assert_eq!(pl[0], pl[1 + 3 * 16]); // (0,0) and (1,3) same column
        assert_ne!(pl[0], pl[2]); // (2,0) next column
    }

    #[test]
    fn deterministic_across_runs() {
        let c = grid(16, 16);
        let a = mj_partition(&c, 13, &MjConfig::default());
        let b = mj_partition(&c, 13, &MjConfig::default());
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_bit_identical_to_sequential() {
        // Tiny grain forces real recursion splits even on this small input.
        let c = grid(32, 32);
        for ord in [PartOrdering::Z, PartOrdering::Gray, PartOrdering::FZ, PartOrdering::MFZ] {
            for np in [2usize, 13, 64, 1024] {
                let cfg = MjConfig {
                    ordering: ord,
                    longest_dim: np % 2 == 0,
                    uneven_prime: np == 13,
                };
                let seq = mj_partition_par(&c, np, &cfg, Parallelism::sequential());
                for threads in [2, 8] {
                    let par = mj_partition_par(
                        &c,
                        np,
                        &cfg,
                        Parallelism::threads(threads).with_grain(8),
                    );
                    assert_eq!(par, seq, "{ord:?} np={np} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn axes_permutation_matches_materialized_permute() {
        let c = grid(16, 8);
        let cfg = MjConfig {
            ordering: PartOrdering::FZ,
            longest_dim: false,
            uneven_prime: false,
        };
        let perm = [1usize, 0];
        let mut scratch = MjScratch::new();
        let mut part = Vec::new();
        mj_partition_axes_into(
            &c,
            &perm,
            16,
            &cfg,
            Parallelism::sequential(),
            &mut scratch,
            &mut part,
        );
        let want = mj_partition(&c.permute_axes(&perm), 16, &cfg);
        assert_eq!(part, want);
    }

    #[test]
    fn recursion_depth_matches_split_rule() {
        assert_eq!(recursion_depth(1, false), 0);
        assert_eq!(recursion_depth(2, false), 1);
        assert_eq!(recursion_depth(16, false), 4);
        // 7 -> (4,3), 3 -> (2,1): depth 3.
        assert_eq!(recursion_depth(7, false), 3);
        // Uneven prime splits can only deepen or match the even split at
        // the same part count's power-of-two depth bound.
        assert!(recursion_depth(10_800, true) >= recursion_depth(16, false));
    }

    #[test]
    fn partition_emits_mj_instant_when_recording() {
        let c = grid(8, 8);
        let cfg = MjConfig::default();
        let baseline = mj_partition_par(&c, 16, &cfg, Parallelism::sequential());
        let (traced, events) = crate::obs::capture(|| {
            mj_partition_par(&c, 16, &cfg, Parallelism::sequential())
        });
        // Tracing never changes the partition.
        assert_eq!(traced, baseline);
        let mj: Vec<_> = events.iter().filter(|e| e.name == "mj.partition").collect();
        assert_eq!(mj.len(), 1);
        let fields: std::collections::BTreeMap<_, _> =
            mj[0].fields.iter().copied().collect();
        assert_eq!(fields["parts"], 16.0);
        assert_eq!(fields["points"], 64.0);
        assert_eq!(fields["depth"], 4.0);
        assert_eq!(fields["imbalance"], 1.0);
    }

    #[test]
    fn scratch_reuse_across_calls() {
        let mut scratch = MjScratch::new();
        let mut part = Vec::new();
        let a = grid(8, 8);
        let b = grid(5, 3);
        let cfg = MjConfig::default();
        mj_partition_into(&a, 16, &cfg, Parallelism::sequential(), &mut scratch, &mut part);
        assert_eq!(part.len(), 64);
        let first = part.clone();
        // Smaller problem next: the scratch shrinks/overwrites cleanly.
        mj_partition_into(&b, 5, &cfg, Parallelism::sequential(), &mut scratch, &mut part);
        assert_eq!(part.len(), 15);
        // And the original result is reproducible after reuse.
        mj_partition_into(&a, 16, &cfg, Parallelism::sequential(), &mut scratch, &mut part);
        assert_eq!(part, first);
    }
}
