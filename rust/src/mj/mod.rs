//! Multi-Jagged (MJ) geometric partitioning (Section 4.1, Algorithm 2).
//!
//! MJ recursively partitions a coordinate set along one dimension at a
//! time. Used as the sequential kernel of the task-mapping algorithm
//! (Section 4.2): the partition runs over both the task coordinates and the
//! processor coordinates, and part numbers — assigned by a space-filling
//! ordering (Z, Gray, FZ, MFZ) — tie the two together.
//!
//! Two modes:
//! * [`mj_partition`] — recursive (possibly uneven) **bisection** with the
//!   SFC part numbering of Algorithm 2. This is the mapping path.
//! * [`mj_multisection`] — the general multisection form with an explicit
//!   per-level part-count vector (`P = Π P_i`, Fig. 1), Z numbering.
//!
//! Cuts are found by exact selection (`select_nth_unstable`) on
//! (coordinate, index) keys: deterministic, tie-stable, O(n) per level.
//! Points with identical coordinates are only separated when a cut lands
//! inside their run, and subtree part numbers are contiguous — so e.g. the
//! ranks of one multicore node (identical router coordinates) always
//! receive a contiguous range of part numbers.

pub mod multisection;

pub use multisection::mj_multisection;

use crate::geom::Coords;
use crate::sfc::PartOrdering;

/// MJ configuration for the bisection/mapping path.
#[derive(Clone, Copy, Debug)]
pub struct MjConfig {
    /// Part-numbering ordering (Algorithm 2). `Hilbert` is not an MJ flip
    /// rule and is rejected here (handled by the mapping layer).
    pub ordering: PartOrdering,
    /// Cut perpendicular to the longest dimension of the current region
    /// (Section 4.3) instead of strictly alternating dimensions.
    pub longest_dim: bool,
    /// Uneven bisection by the largest prime divisor of the part count
    /// (the Z2_2/Z2_3 optimization of Section 5.3.1): splitting 10,800
    /// parts as 6,480 + 4,320 instead of 5,400 + 5,400 keeps nodes intact
    /// deeper into the hierarchy.
    pub uneven_prime: bool,
}

impl Default for MjConfig {
    fn default() -> Self {
        MjConfig {
            ordering: PartOrdering::FZ,
            longest_dim: true,
            uneven_prime: false,
        }
    }
}

/// Partition `coords` into `num_parts` parts; returns the part id of every
/// point. Part sizes are balanced: `n mod num_parts` low-numbered parts get
/// one extra point.
pub fn mj_partition(coords: &Coords, num_parts: usize, cfg: &MjConfig) -> Vec<u32> {
    assert!(num_parts >= 1);
    assert!(
        cfg.ordering != PartOrdering::Hilbert,
        "Hilbert is not an MJ part numbering; use mapping::hilbert_mapping"
    );
    let n = coords.len();
    assert!(
        num_parts <= n,
        "cannot make {num_parts} nonempty parts from {n} points"
    );
    let dim = coords.dim();
    // Working copies: MJ's orderings flip coordinates in place (Alg. 2).
    let mut axes: Vec<Vec<f64>> = (0..dim).map(|d| coords.axis(d).to_vec()).collect();
    let mut idx: Vec<u32> = (0..n as u32).collect();
    let mut part = vec![0u32; n];
    let extra = n % num_parts;
    let base = n / num_parts;
    let mut st = State {
        axes: &mut axes,
        part: &mut part,
        base,
        extra,
        cfg,
        dim,
    };
    bisect(&mut st, &mut idx, 0, num_parts, 0);
    part
}

struct State<'a> {
    axes: &'a mut Vec<Vec<f64>>,
    part: &'a mut Vec<u32>,
    /// Global part-size rule: part `p` holds `base + (p < extra)` points.
    base: usize,
    extra: usize,
    cfg: &'a MjConfig,
    dim: usize,
}

/// Number of points owned by parts `[offset, offset + np)`.
fn span_count(st: &State, offset: usize, np: usize) -> usize {
    let extra_here = st.extra.saturating_sub(offset).min(np);
    np * st.base + extra_here
}

/// Largest prime factor (num_parts in this codebase is at most ~2^21, so
/// trial division is instantaneous).
pub fn largest_prime_factor(mut n: usize) -> usize {
    let mut largest = 1;
    let mut f = 2;
    while f * f <= n {
        while n % f == 0 {
            largest = f;
            n /= f;
        }
        f += 1;
    }
    if n > 1 {
        largest = n;
    }
    largest
}

/// How to split `np` parts between the two sides of a bisection.
fn split_parts(np: usize, uneven_prime: bool) -> (usize, usize) {
    if uneven_prime {
        let p = largest_prime_factor(np);
        let np_l = np / p * p.div_ceil(2);
        (np_l, np - np_l)
    } else {
        (np.div_ceil(2), np / 2)
    }
}

fn bisect(st: &mut State, idx: &mut [u32], offset: usize, np: usize, level: usize) {
    if np == 1 {
        for &i in idx.iter() {
            st.part[i as usize] = offset as u32;
        }
        return;
    }
    // Dimension to cut.
    let d = if st.cfg.longest_dim {
        longest_dim_of(st, idx)
    } else {
        level % st.dim
    };
    let (np_l, np_r) = split_parts(np, st.cfg.uneven_prime);
    let count_l = span_count(st, offset, np_l);
    debug_assert!(count_l >= 1 && count_l < idx.len() + 1);
    // Exact selection on (coordinate, point index): deterministic ties.
    {
        let axis: &Vec<f64> = &st.axes[d];
        idx.select_nth_unstable_by(count_l - 1, |&a, &b| {
            let (ca, cb) = (axis[a as usize], axis[b as usize]);
            ca.partial_cmp(&cb).unwrap().then(a.cmp(&b))
        });
    }
    let (left, right) = idx.split_at_mut(count_l);
    // Algorithm 2 flip rules.
    match st.cfg.ordering {
        PartOrdering::Z => {}
        PartOrdering::Gray => {
            for &i in right.iter() {
                for axis in st.axes.iter_mut() {
                    axis[i as usize] = -axis[i as usize];
                }
            }
        }
        PartOrdering::FZ => {
            for &i in right.iter() {
                st.axes[d][i as usize] = -st.axes[d][i as usize];
            }
        }
        PartOrdering::MFZ => {
            // MFZ flips the LOWER half instead (Section 4.3).
            for &i in left.iter() {
                st.axes[d][i as usize] = -st.axes[d][i as usize];
            }
        }
        PartOrdering::Hilbert => unreachable!(),
    }
    bisect(st, left, offset, np_l, level + 1);
    bisect(st, right, offset + np_l, np_r, level + 1);
}

fn longest_dim_of(st: &State, idx: &[u32]) -> usize {
    let mut best = 0usize;
    let mut best_ext = f64::NEG_INFINITY;
    for d in 0..st.dim {
        let axis = &st.axes[d];
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &i in idx {
            let v = axis[i as usize];
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        let ext = hi - lo;
        if ext > best_ext {
            best_ext = ext;
            best = d;
        }
    }
    best
}

/// Part sizes produced by [`mj_partition`] for `n` points into `np` parts.
pub fn part_sizes(n: usize, np: usize) -> Vec<usize> {
    let base = n / np;
    let extra = n % np;
    (0..np).map(|p| base + usize::from(p < extra)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;

    fn grid(nx: usize, ny: usize) -> Coords {
        stencil_graph(&[nx, ny], false, 1.0).coords
    }

    fn counts(parts: &[u32], np: usize) -> Vec<usize> {
        let mut c = vec![0usize; np];
        for &p in parts {
            c[p as usize] += 1;
        }
        c
    }

    #[test]
    fn balanced_power_of_two() {
        let c = grid(8, 8);
        for ord in [PartOrdering::Z, PartOrdering::Gray, PartOrdering::FZ, PartOrdering::MFZ] {
            let cfg = MjConfig {
                ordering: ord,
                longest_dim: false,
                uneven_prime: false,
            };
            let parts = mj_partition(&c, 16, &cfg);
            assert_eq!(counts(&parts, 16), vec![4; 16], "{ord:?}");
        }
    }

    #[test]
    fn balanced_non_power_of_two() {
        let c = grid(10, 10);
        let parts = mj_partition(&c, 7, &MjConfig::default());
        let sizes = counts(&parts, 7);
        assert_eq!(sizes, part_sizes(100, 7));
        assert_eq!(sizes.iter().sum::<usize>(), 100);
    }

    #[test]
    fn one_part_trivial() {
        let c = grid(4, 4);
        let parts = mj_partition(&c, 1, &MjConfig::default());
        assert!(parts.iter().all(|&p| p == 0));
    }

    #[test]
    fn one_point_per_part() {
        let c = grid(4, 4);
        let parts = mj_partition(&c, 16, &MjConfig::default());
        let mut s: Vec<u32> = parts.clone();
        s.sort_unstable();
        assert_eq!(s, (0..16).collect::<Vec<u32>>());
    }

    #[test]
    fn z_order_on_square_grid_matches_morton() {
        // 4x4 grid into 16 parts, alternating dims starting with x, Z
        // ordering: part number = Morton(y, x) with x cut first.
        let c = grid(4, 4);
        let cfg = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: false,
            uneven_prime: false,
        };
        let parts = mj_partition(&c, 16, &cfg);
        for y in 0..4usize {
            for x in 0..4usize {
                let i = y * 4 + x;
                // First cut on x (bit 3), then y (bit 2), then x (bit 1),
                // then y (bit 0).
                let expect = ((x >> 1) << 3) | ((y >> 1) << 2) | ((x & 1) << 1) | (y & 1);
                assert_eq!(parts[i] as usize, expect, "({x},{y})");
            }
        }
    }

    #[test]
    fn parts_are_spatially_contiguous_z() {
        // Each part of an 8x8 grid into 4 parts must be a 4x4 quadrant.
        let c = grid(8, 8);
        let cfg = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: false,
            uneven_prime: false,
        };
        let parts = mj_partition(&c, 4, &cfg);
        for y in 0..8usize {
            for x in 0..8usize {
                let expect = (x / 4) * 2 + y / 4;
                assert_eq!(parts[y * 8 + x] as usize, expect, "({x},{y})");
            }
        }
    }

    #[test]
    fn fz_differs_from_z_in_numbering_not_membership() {
        // FZ flips coordinates, which changes which *numbers* parts get but
        // (for one level) not the cut membership.
        let c = grid(8, 8);
        let mk = |ordering| MjConfig {
            ordering,
            longest_dim: false,
            uneven_prime: false,
        };
        let z = mj_partition(&c, 64, &mk(PartOrdering::Z));
        let fz = mj_partition(&c, 64, &mk(PartOrdering::FZ));
        assert_ne!(z, fz);
        // Same multiset of sizes.
        assert_eq!(counts(&z, 64), counts(&fz, 64));
    }

    #[test]
    fn uneven_prime_split() {
        assert_eq!(split_parts(10800, true), (6480, 4320)); // paper's example
        assert_eq!(split_parts(8, true), (4, 4));
        assert_eq!(split_parts(6, true), (4, 2)); // p=3: 2/3 | 1/3
        assert_eq!(split_parts(7, true), (4, 3));
        assert_eq!(split_parts(10800, false), (5400, 5400));
    }

    #[test]
    fn largest_prime_factor_basic() {
        assert_eq!(largest_prime_factor(10800), 5);
        assert_eq!(largest_prime_factor(8), 2);
        assert_eq!(largest_prime_factor(7), 7);
        assert_eq!(largest_prime_factor(1), 1);
        assert_eq!(largest_prime_factor(97 * 4), 97);
    }

    #[test]
    fn identical_points_get_contiguous_parts() {
        // 4 ranks per "node" with identical coordinates: each node's ranks
        // must occupy a contiguous part-number range.
        let mut c = Coords::new(2);
        for node in 0..4 {
            for _ in 0..4 {
                c.push(&[(node % 2) as f64, (node / 2) as f64]);
            }
        }
        let parts = mj_partition(&c, 16, &MjConfig::default());
        for node in 0..4 {
            let mut ps: Vec<u32> = (0..4).map(|r| parts[node * 4 + r]).collect();
            ps.sort_unstable();
            for w in ps.windows(2) {
                assert_eq!(w[1], w[0] + 1, "node {node} parts not contiguous: {ps:?}");
            }
        }
    }

    #[test]
    fn longest_dim_cuts_the_long_axis_first() {
        // 16x2 grid into 2 parts: longest-dim must cut x, giving 8x2 halves.
        let c = grid(16, 2);
        let cfg = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: true,
            uneven_prime: false,
        };
        let parts = mj_partition(&c, 2, &cfg);
        for y in 0..2 {
            for x in 0..16 {
                let expect = u32::from(x >= 8);
                assert_eq!(parts[y * 16 + x], expect);
            }
        }
    }

    #[test]
    fn fig2_longest_dim() {
        // Fig. 2: on a 16x4 grid, three levels of longest-dimension
        // partitioning cut x, x, then x again (extent 16 -> 8 -> 4 = y-ext
        // tie broken toward x) — whereas strictly alternating cuts x, y, x.
        // The observable effect: with alternating cuts the 8 parts are
        // 4x2 blocks; with longest-dim they are 2x4 columns.
        let c = grid(16, 4);
        let alt = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: false,
            uneven_prime: false,
        };
        let lng = MjConfig {
            ordering: PartOrdering::Z,
            longest_dim: true,
            uneven_prime: false,
        };
        let pa = mj_partition(&c, 8, &alt);
        let pl = mj_partition(&c, 8, &lng);
        // Alternating: part of (x,y) constant on 4x2 blocks.
        assert_eq!(pa[0], pa[3 + 16]); // (0,0) and (3,1) same 4x2 block
        assert_ne!(pa[0], pa[2 * 16]); // (0,2) different y-half
        // Longest-dim: columns of width 2 spanning all y.
        assert_eq!(pl[0], pl[1 + 3 * 16]); // (0,0) and (1,3) same column
        assert_ne!(pl[0], pl[2]); // (2,0) next column
    }

    #[test]
    fn deterministic_across_runs() {
        let c = grid(16, 16);
        let a = mj_partition(&c, 13, &MjConfig::default());
        let b = mj_partition(&c, 13, &MjConfig::default());
        assert_eq!(a, b);
    }
}
