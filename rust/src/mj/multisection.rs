//! General multisection MJ (Section 4.1, Fig. 1): partition into
//! `P = Π P_i` parts in `RD = len(counts)` levels, `P_i` parts per level
//! with `P_i - 1` parallel cuts, alternating (or longest) dimensions.
//! Part numbers are assigned lexicographically per level (Z-style).
//!
//! Unlike the bisection path, multisection never flips coordinates, so the
//! recursion reads `Coords` directly (no working axis copies) and only the
//! index permutation and output buffer live in the [`MjScratch`] arena. The
//! per-level slices own disjoint point-index sets, so they recurse
//! concurrently under the same determinism guarantee as `mj_partition`:
//! bit-identical output at every thread count.

use super::MjScratch;
use crate::geom::Coords;
use crate::par::{self, Parallelism, SharedSlice};

/// Multisection configuration: parts per recursion level.
#[derive(Clone, Debug)]
pub struct MultisectionConfig {
    /// `P_i` per level; the total part count is the product.
    pub counts: Vec<usize>,
    /// Cut along the longest dimension of each region instead of cycling.
    pub longest_dim: bool,
}

impl MultisectionConfig {
    /// Equal split of `p` into `rd` levels: factors as close to `p^(1/rd)`
    /// as possible (requires `p` to be a perfect power when uniform);
    /// falls back to greedy factorization.
    pub fn levels(p: usize, rd: usize) -> Self {
        assert!(rd >= 1);
        let target = (p as f64).powf(1.0 / rd as f64).round() as usize;
        let mut counts = Vec::with_capacity(rd);
        let mut rem = p;
        for level in 0..rd {
            if level == rd - 1 {
                counts.push(rem);
                rem = 1;
            } else {
                // Largest divisor of rem that is <= target (>= 2).
                let mut f = target.max(2).min(rem);
                while rem % f != 0 {
                    f -= 1;
                }
                counts.push(f.max(1));
                rem /= f.max(1);
            }
        }
        assert_eq!(counts.iter().product::<usize>(), p);
        MultisectionConfig {
            counts,
            longest_dim: false,
        }
    }

    pub fn total_parts(&self) -> usize {
        self.counts.iter().product()
    }
}

/// Partition into `Π counts` parts. Returns part id per point. Runs with
/// the auto thread budget; the result does not depend on the budget.
pub fn mj_multisection(coords: &Coords, cfg: &MultisectionConfig) -> Vec<u32> {
    mj_multisection_par(coords, cfg, Parallelism::auto())
}

/// [`mj_multisection`] with an explicit thread budget.
pub fn mj_multisection_par(
    coords: &Coords,
    cfg: &MultisectionConfig,
    par: Parallelism,
) -> Vec<u32> {
    let mut scratch = MjScratch::new();
    let mut part = Vec::new();
    mj_multisection_into(coords, cfg, par, &mut scratch, &mut part);
    part
}

/// Zero-allocation (in steady state) form: writes part ids into `part`,
/// reusing `scratch` for the index permutation.
pub fn mj_multisection_into(
    coords: &Coords,
    cfg: &MultisectionConfig,
    par: Parallelism,
    scratch: &mut MjScratch,
    part: &mut Vec<u32>,
) {
    let n = coords.len();
    let p = cfg.total_parts();
    assert!(p >= 1 && p <= n);
    let dim = coords.dim();
    scratch.idx.clear();
    scratch.idx.extend(0..n as u32);
    part.clear();
    part.resize(n, 0);
    let ctx = MsCtx {
        coords,
        part: SharedSlice::new(part.as_mut_slice()),
        counts: &cfg.counts,
        longest_dim: cfg.longest_dim,
        // Global balanced sizing as in the bisection path.
        base: n / p,
        extra: n % p,
        dim,
    };
    rec(&ctx, &mut scratch.idx, 0, 0, par);
}

/// Shared recursion context. Safety: as in `mj::bisect`, each `rec` call
/// owns the point indices in its `idx` sub-slice and only writes `part` at
/// those indices; sibling slices are disjoint.
struct MsCtx<'a> {
    coords: &'a Coords,
    part: SharedSlice<'a, u32>,
    counts: &'a [usize],
    longest_dim: bool,
    base: usize,
    extra: usize,
    dim: usize,
}

impl MsCtx<'_> {
    /// Count of points owned by parts `[offset, offset + k)`.
    fn span(&self, offset: usize, k: usize) -> usize {
        k * self.base + self.extra.saturating_sub(offset).min(k)
    }
}

fn rec(cx: &MsCtx, idx: &mut [u32], level: usize, offset: usize, par: Parallelism) {
    if level == cx.counts.len() {
        for &i in idx.iter() {
            // SAFETY: this call owns point index `i`.
            unsafe { cx.part.set(i as usize, offset as u32) };
        }
        return;
    }
    let region_len = idx.len();
    let pi = cx.counts[level];
    // Parts remaining below this level.
    let below: usize = cx.counts[level + 1..].iter().product();
    let d = if cx.longest_dim {
        let mut best = 0;
        let mut ext_best = f64::NEG_INFINITY;
        for dd in 0..cx.dim {
            let axis = cx.coords.axis(dd);
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for &i in idx.iter() {
                let v = axis[i as usize];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if hi - lo > ext_best {
                ext_best = hi - lo;
                best = dd;
            }
        }
        best
    } else {
        level % cx.dim
    };
    // Multisection: slice off the first `span` points pi-1 times. The
    // slicing itself is sequential (each cut orders the remainder), but the
    // resulting sibling slices recurse concurrently.
    let axis = cx.coords.axis(d);
    let mut chunks: Vec<(&mut [u32], usize)> = Vec::with_capacity(pi);
    let mut rest = idx;
    let mut off = offset;
    for s in 0..pi {
        let take = if s + 1 == pi {
            rest.len()
        } else {
            cx.span(off, below)
        };
        if take < rest.len() {
            rest.select_nth_unstable_by(take - 1, |&a, &b| {
                axis[a as usize]
                    .partial_cmp(&axis[b as usize])
                    .unwrap()
                    .then(a.cmp(&b))
            });
        }
        let (chunk, r) = std::mem::take(&mut rest).split_at_mut(take);
        chunks.push((chunk, off));
        rest = r;
        off += below;
    }
    if par.num_threads() >= 2 && region_len >= par.grain() {
        par::for_each_vec(par, chunks, &|p, (chunk, off)| {
            rec(cx, chunk, level + 1, off, p)
        });
    } else {
        for (chunk, off) in chunks {
            rec(cx, chunk, level + 1, off, par);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;

    fn grid(nx: usize, ny: usize) -> Coords {
        stencil_graph(&[nx, ny], false, 1.0).coords
    }

    #[test]
    fn fig1_rd3_is_4x4x4_jagged() {
        // 64 parts in 3 levels of 4 over a 16x16 grid (Fig. 1 left).
        let c = grid(16, 16);
        let cfg = MultisectionConfig {
            counts: vec![4, 4, 4],
            longest_dim: false,
        };
        let parts = mj_multisection(&c, &cfg);
        let mut sizes = vec![0usize; 64];
        for &p in &parts {
            sizes[p as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s == 4), "{sizes:?}");
        // Level-1 cuts are vertical: parts 0..15 occupy x in [0,4).
        for (i, &p) in parts.iter().enumerate() {
            let x = i % 16;
            assert_eq!((p / 16) as usize, x / 4, "point ({x},{}) part {p}", i / 16);
        }
    }

    #[test]
    fn fig1_rd6_equals_rcb_sizes() {
        // RD = log2(P): multisection degenerates to bisection (Fig. 1
        // right); sizes stay balanced.
        let c = grid(16, 16);
        let cfg = MultisectionConfig {
            counts: vec![2; 6],
            longest_dim: false,
        };
        let parts = mj_multisection(&c, &cfg);
        let mut sizes = vec![0usize; 64];
        for &p in &parts {
            sizes[p as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s == 4));
    }

    #[test]
    fn levels_factorization() {
        let cfg = MultisectionConfig::levels(64, 3);
        assert_eq!(cfg.counts.iter().product::<usize>(), 64);
        assert_eq!(cfg.counts, vec![4, 4, 4]);
        let cfg = MultisectionConfig::levels(64, 6);
        assert_eq!(cfg.counts, vec![2; 6]);
        let cfg = MultisectionConfig::levels(360, 3);
        assert_eq!(cfg.counts.iter().product::<usize>(), 360);
    }

    #[test]
    fn uneven_total_distributes_remainder() {
        let c = grid(10, 7); // 70 points
        let cfg = MultisectionConfig {
            counts: vec![3, 4],
            longest_dim: false,
        };
        let parts = mj_multisection(&c, &cfg);
        let mut sizes = vec![0usize; 12];
        for &p in &parts {
            sizes[p as usize] += 1;
        }
        // 70 = 12*5 + 10: ten parts of 6, two of 5.
        assert_eq!(sizes.iter().sum::<usize>(), 70);
        assert!(sizes.iter().all(|&s| s == 5 || s == 6), "{sizes:?}");
    }

    #[test]
    fn parallel_bit_identical_to_sequential() {
        let c = grid(24, 18);
        for cfg in [
            MultisectionConfig {
                counts: vec![4, 4, 4],
                longest_dim: false,
            },
            MultisectionConfig {
                counts: vec![3, 4],
                longest_dim: true,
            },
            MultisectionConfig {
                counts: vec![2; 6],
                longest_dim: false,
            },
        ] {
            let seq = mj_multisection_par(&c, &cfg, Parallelism::sequential());
            for threads in [2, 8] {
                let par = mj_multisection_par(
                    &c,
                    &cfg,
                    Parallelism::threads(threads).with_grain(8),
                );
                assert_eq!(par, seq, "{cfg:?} threads={threads}");
            }
        }
    }
}
