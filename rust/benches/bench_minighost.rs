//! Bench: regenerate Figs 13-15 (MiniGhost weak scaling on the Cray XK7
//! model). Small scale by default; `--full` for 8K-128K procs.

use taskmap::coordinator::{experiments, Ctx};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let ctx = Ctx::new(full, 42, false);
    eprintln!("backend: {}", ctx.backend_name());
    for id in ["fig13", "fig14", "fig15"] {
        let t0 = std::time::Instant::now();
        for t in experiments::run(id, &ctx).unwrap() {
            println!("{}", t.markdown());
        }
        println!("[{id}] regenerated in {:.1}s\n", t0.elapsed().as_secs_f64());
    }
}
