//! The rotation sweep (Section 4.3): the map-and-score hot path across
//! thread counts, plus the raw WeightedHops kernel and the artifact-backed
//! backend. Results land in `BENCH_mapping.json` (merge-on-write; override
//! the path with `TASKMAP_BENCH_OUT`) so the speedup trajectory is diffable
//! across commits.

use taskmap::apps::stencil::stencil_graph;
use taskmap::machine::{Allocation, Torus};
use taskmap::mapping::rotations::{
    rotation_sweep, score_mappings_par, NativeBackend, SweepConfig, WhopsBackend,
};
use taskmap::mapping::MapConfig;
use taskmap::metrics::native::{batched_weighted_hops_native, batched_weighted_hops_native_par};
use taskmap::par::Parallelism;
use taskmap::runtime::PjrtBackend;
use taskmap::testutil::bench::{bench, bench_quick, BenchRecorder};
use taskmap::testutil::Rng;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    println!("== rotation sweep / WeightedHops backends ==");

    // Raw kernel comparison at the main artifact shape, across threads.
    let (r, e, d) = (36usize, 32_768usize, 6usize);
    let mut rng = Rng::new(1);
    let dims: Vec<f32> = (0..d).map(|_| 16.0).collect();
    let wrap = vec![1f32; d];
    let src: Vec<f32> = (0..r * e * d).map(|_| rng.below(16) as f32).collect();
    let dst: Vec<f32> = (0..r * e * d).map(|_| rng.below(16) as f32).collect();
    let w: Vec<f32> = (0..e).map(|_| 1.0).collect();
    for threads in THREAD_COUNTS {
        let result = bench(
            &format!("whops_kernel/r={r}/e={e}/d={d}/threads={threads}"),
            || {
                batched_weighted_hops_native_par(
                    &src,
                    &dst,
                    &w,
                    &dims,
                    &wrap,
                    r,
                    e,
                    d,
                    Parallelism::threads(threads),
                )
            },
        );
        rec.record(&result, &[("threads", threads as f64)]);
    }
    if let Some(backend) = PjrtBackend::try_default() {
        let result = bench_quick(&format!("whops_kernel/r={r}/e={e}/d={d}/pjrt-artifact"), || {
            backend.eval_batch(&src, &dst, &w, &dims, &wrap, r, e, d)
        });
        rec.record(&result, &[]);
    } else {
        println!("(artifacts not built; run `make artifacts` for the artifact-backend rows)");
    }

    // End-to-end sweep on a 16x16x16 stencil -> 4096-node torus, across
    // thread counts. This is the headline number: the candidate fan-out +
    // proc-partition memoization + scratch reuse, all at once.
    let g = stencil_graph(&[16, 16, 16], false, 1.0);
    let torus = Torus::torus(&[16, 16, 16]);
    let alloc = Allocation {
        machine: torus.into(),
        core_router: (0..4096u32).collect(),
        core_node: (0..4096u32).collect(),
        ranks_per_node: 1,
    };
    let p = alloc.proc_coords();
    let mut sweep_ns: Vec<(usize, f64)> = Vec::new();
    for threads in THREAD_COUNTS {
        let mut sweep = SweepConfig {
            max_candidates: 12,
            ..Default::default()
        };
        sweep.spec.threads = threads;
        let result = bench_quick(
            &format!("rotation_sweep/tasks=4096/candidates=12/threads={threads}"),
            || {
                rotation_sweep(
                    &g,
                    &g.coords,
                    &p,
                    &alloc,
                    &MapConfig::default(),
                    &sweep,
                    &NativeBackend,
                )
            },
        );
        rec.record(&result, &[("threads", threads as f64)]);
        sweep_ns.push((threads, result.per_iter_ns()));
    }
    if let (Some((_, t1)), Some((_, t8))) = (
        sweep_ns.iter().find(|(t, _)| *t == 1),
        sweep_ns.iter().find(|(t, _)| *t == 8),
    ) {
        let speedup = t1 / t8;
        println!("rotation_sweep speedup at 8 threads vs sequential: {speedup:.2}x");
        rec.record_scalar("rotation_sweep/speedup_8t_vs_1t", "speedup", speedup);
    }

    // Scoring only (mapping excluded) to separate partition vs evaluation.
    let mappings: Vec<Vec<u32>> = (0..12)
        .map(|s| {
            let mut m: Vec<u32> = (0..4096).collect();
            let mut rng = Rng::new(s);
            rng.shuffle(&mut m);
            m
        })
        .collect();
    for threads in THREAD_COUNTS {
        let result = bench(
            &format!("score_mappings/candidates=12/edges=11k/threads={threads}"),
            || {
                score_mappings_par(
                    &g,
                    &mappings,
                    &alloc,
                    &NativeBackend,
                    32768,
                    Parallelism::threads(threads),
                )
            },
        );
        rec.record(&result, &[("threads", threads as f64)]);
    }
    if let Some(backend) = PjrtBackend::try_default() {
        let result = bench_quick("score_mappings/candidates=12/edges=11k/pjrt-artifact", || {
            score_mappings_par(
                &g,
                &mappings,
                &alloc,
                &backend,
                32768,
                Parallelism::sequential(),
            )
        });
        rec.record(&result, &[]);
    }

    // Keep the sequential raw-kernel reference row for cross-commit
    // comparability with the pre-parallel trajectory.
    let result = bench(&format!("whops_kernel/r={r}/e={e}/d={d}/sequential-reference"), || {
        batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, r, e, d)
    });
    rec.record(&result, &[("threads", 1.0)]);

    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
