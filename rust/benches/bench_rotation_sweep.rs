//! The rotation sweep (Section 4.3): native vs PJRT WeightedHops scoring —
//! the L1/L2/runtime integration hot path.

use taskmap::apps::stencil::stencil_graph;
use taskmap::machine::{Allocation, Torus};
use taskmap::mapping::rotations::{
    rotation_sweep, score_mappings, NativeBackend, SweepConfig, WhopsBackend,
};
use taskmap::mapping::MapConfig;
use taskmap::metrics::native::batched_weighted_hops_native;
use taskmap::runtime::PjrtBackend;
use taskmap::testutil::bench::{bench, bench_quick};
use taskmap::testutil::Rng;

fn main() {
    println!("== rotation sweep / WeightedHops backends ==");
    // Raw kernel comparison at the main artifact shape.
    let (r, e, d) = (36usize, 32_768usize, 6usize);
    let mut rng = Rng::new(1);
    let dims: Vec<f32> = (0..d).map(|_| 16.0).collect();
    let wrap = vec![1f32; d];
    let src: Vec<f32> = (0..r * e * d).map(|_| rng.below(16) as f32).collect();
    let dst: Vec<f32> = (0..r * e * d).map(|_| rng.below(16) as f32).collect();
    let w: Vec<f32> = (0..e).map(|_| 1.0).collect();
    bench(&format!("native whops r={r} e={e} d={d}"), || {
        batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, r, e, d)
    });
    if let Some(backend) = PjrtBackend::try_default() {
        bench_quick(&format!("pjrt   whops r={r} e={e} d={d}"), || {
            backend.eval_batch(&src, &dst, &w, &dims, &wrap, r, e, d)
        });
    } else {
        println!("(pjrt artifacts not built; run `make artifacts` for the PJRT rows)");
    }

    // End-to-end sweep on a 16x16x16 stencil -> 4096-node torus.
    let g = stencil_graph(&[16, 16, 16], false, 1.0);
    let torus = Torus::torus(&[16, 16, 16]);
    let alloc = Allocation {
        torus,
        core_router: (0..4096u32).collect(),
        core_node: (0..4096u32).collect(),
        ranks_per_node: 1,
    };
    let p = alloc.proc_coords();
    let sweep = SweepConfig {
        max_candidates: 12,
        ..Default::default()
    };
    bench_quick("rotation_sweep 12 candidates, 4096 tasks (native)", || {
        rotation_sweep(
            &g,
            &g.coords,
            &p,
            &alloc,
            &MapConfig::default(),
            &sweep,
            &NativeBackend,
        )
    });
    // Scoring only (mapping excluded) to separate partition vs evaluation.
    let mappings: Vec<Vec<u32>> = (0..12)
        .map(|s| {
            let mut m: Vec<u32> = (0..4096).collect();
            let mut rng = Rng::new(s);
            rng.shuffle(&mut m);
            m
        })
        .collect();
    bench("score 12 mappings x 11k edges (native)", || {
        score_mappings(&g, &mappings, &alloc, &NativeBackend, 32768)
    });
    if let Some(backend) = PjrtBackend::try_default() {
        bench_quick("score 12 mappings x 11k edges (pjrt)", || {
            score_mappings(&g, &mappings, &alloc, &backend, 32768)
        });
    }
}
