//! Microbenchmarks: the MJ partitioner (the L3 hot path of Algorithm 1)
//! across sizes, orderings, and cut-selection policies.

use taskmap::geom::Coords;
use taskmap::mj::{mj_partition, MjConfig};
use taskmap::sfc::hilbert::hilbert_sort_f64;
use taskmap::sfc::PartOrdering;
use taskmap::testutil::bench::bench;
use taskmap::testutil::Rng;

fn random_coords(n: usize, dim: usize, seed: u64) -> Coords {
    let mut rng = Rng::new(seed);
    let mut c = Coords::with_capacity(dim, n);
    let mut p = vec![0f64; dim];
    for _ in 0..n {
        for x in p.iter_mut() {
            *x = rng.below(1 << 16) as f64;
        }
        c.push(&p);
    }
    c
}

fn main() {
    println!("== MJ partitioner ==");
    for &n in &[4_096usize, 65_536, 262_144] {
        let c = random_coords(n, 3, 42);
        let cfg = MjConfig::default();
        bench(&format!("mj_partition FZ longest n={n} p={n}"), || {
            mj_partition(&c, n, &cfg)
        });
    }
    let c = random_coords(65_536, 3, 42);
    for ordering in [PartOrdering::Z, PartOrdering::Gray, PartOrdering::FZ] {
        let cfg = MjConfig {
            ordering,
            longest_dim: false,
            uneven_prime: false,
        };
        bench(
            &format!("mj_partition {} alternating n=65536", ordering.name()),
            || mj_partition(&c, 65_536, &cfg),
        );
    }
    // Coarse partitions (tnum >> parts): the simultaneous map+partition
    // case.
    let cfg = MjConfig::default();
    bench("mj_partition FZ n=262144 p=1024", || {
        mj_partition(&random_coords(262_144, 3, 7), 1_024, &cfg)
    });
    // Hilbert ranking for comparison (the H ordering path).
    bench("hilbert_sort_f64 n=65536 d=3", || {
        hilbert_sort_f64(&c, 16)
    });
}
