//! Microbenchmarks: the MJ partitioner (the L3 hot path of Algorithm 1)
//! across sizes, orderings, cut-selection policies, and thread budgets.
//! Results merge into `BENCH_mapping.json` alongside the rotation-sweep
//! trajectory.

use taskmap::geom::Coords;
use taskmap::mj::{mj_partition, mj_partition_into, mj_partition_par, MjConfig, MjScratch};
use taskmap::par::Parallelism;
use taskmap::sfc::hilbert::hilbert_sort_f64;
use taskmap::sfc::PartOrdering;
use taskmap::testutil::bench::{bench, BenchRecorder};
use taskmap::testutil::graphs::random_points;

fn random_coords(n: usize, dim: usize, seed: u64) -> Coords {
    random_points(n, dim, 65_536.0, seed)
}

fn main() {
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    println!("== MJ partitioner ==");
    for &n in &[4_096usize, 65_536, 262_144] {
        let c = random_coords(n, 3, 42);
        let cfg = MjConfig::default();
        for threads in [1usize, 2, 8] {
            let par = Parallelism::threads(threads);
            let result = bench(
                &format!("mj_partition/FZ/longest/n={n}/p={n}/threads={threads}"),
                || mj_partition_par(&c, n, &cfg, par),
            );
            rec.record(&result, &[("threads", threads as f64)]);
        }
        // Scratch-arena reuse (the rotation sweep's steady state): same
        // partition, no per-call allocation of the working axes.
        let mut scratch = MjScratch::new();
        let mut part = Vec::new();
        let result = bench(
            &format!("mj_partition/FZ/longest/n={n}/p={n}/threads=1/scratch-reuse"),
            || {
                mj_partition_into(&c, n, &cfg, Parallelism::sequential(), &mut scratch, &mut part);
                part.len()
            },
        );
        rec.record(&result, &[("threads", 1.0)]);
    }
    let c = random_coords(65_536, 3, 42);
    for ordering in [PartOrdering::Z, PartOrdering::Gray, PartOrdering::FZ] {
        let cfg = MjConfig {
            ordering,
            longest_dim: false,
            uneven_prime: false,
        };
        let result = bench(
            &format!("mj_partition/{}/alternating/n=65536", ordering.name()),
            || mj_partition(&c, 65_536, &cfg),
        );
        rec.record(&result, &[]);
    }
    // Coarse partitions (tnum >> parts): the simultaneous map+partition
    // case.
    let cfg = MjConfig::default();
    let coarse = random_coords(262_144, 3, 7);
    for threads in [1usize, 8] {
        let par = Parallelism::threads(threads);
        let result = bench(
            &format!("mj_partition/FZ/n=262144/p=1024/threads={threads}"),
            || mj_partition_par(&coarse, 1_024, &cfg, par),
        );
        rec.record(&result, &[("threads", threads as f64)]);
    }
    // Hilbert ranking for comparison (the H ordering path).
    let result = bench("hilbert_sort_f64/n=65536/d=3", || hilbert_sort_f64(&c, 16));
    rec.record(&result, &[]);

    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
