//! Bench: regenerate Table 1 (AverageHops per SFC ordering). Small scale by
//! default; `--full` for the paper's sizes.

use taskmap::coordinator::{table1, Ctx};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let ctx = Ctx::new(full, 42, true);
    let t0 = std::time::Instant::now();
    for t in table1::run(&ctx) {
        println!("{}", t.markdown());
    }
    println!("table1 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
