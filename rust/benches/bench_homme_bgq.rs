//! Bench: regenerate Table 2 and Figs 8-9 (HOMME on BG/Q). Small scale by
//! default; `--full` for the paper's 98,304-element / 32K-rank runs.

use taskmap::coordinator::{experiments, Ctx};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let ctx = Ctx::new(full, 42, false);
    eprintln!("backend: {}", ctx.backend_name());
    for id in ["table2", "fig8", "fig9"] {
        let t0 = std::time::Instant::now();
        for t in experiments::run(id, &ctx).unwrap() {
            println!("{}", t.markdown());
        }
        println!("[{id}] regenerated in {:.1}s\n", t0.elapsed().as_secs_f64());
    }
}
