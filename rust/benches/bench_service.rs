//! Bench: the hardened mapping service — request round-trip latency over
//! TCP (ping / flat map / hierarchical map), a saturation smoke test
//! that floods a deliberately tiny pool and reports sustained throughput,
//! shed fraction, and the time-to-shed (how fast overload is answered),
//! plus the result-cache legs (cold vs hot round trips and their speedup
//! ratio) and the batching legs (compatible-request throughput with and
//! without a batch window). Results append to `BENCH_mapping.json`
//! (override with `TASKMAP_BENCH_OUT`).
//!
//! The pre-existing rtt legs pin `"cache":false` so their trajectory keeps
//! measuring the compute path, not the cache.
//!
//! `--smoke` runs a miniature configuration (seconds, CI-sized) whose
//! entries are recorded under `.../smoke` names so they never clobber the
//! full trajectory rows.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use taskmap::coordinator::service::{error_kind, Client, ErrorKind, Service, ServiceConfig};
use taskmap::testutil::bench::{bench_quick, BenchRecorder};
use taskmap::testutil::json::Json;

fn ping_req() -> Json {
    Json::obj(vec![("op", Json::Str("ping".into()))])
}

/// A flat map request over an n-task 1D line (tasks ascending, procs
/// descending — forces real partitioning work, trivially checkable).
fn map_req(n: usize) -> Json {
    let coords = |rev: bool| {
        Json::Arr(
            (0..n)
                .map(|i| {
                    let x = if rev { n - 1 - i } else { i } as f64;
                    Json::Arr(vec![Json::Num(x)])
                })
                .collect(),
        )
    };
    Json::obj(vec![
        ("op", Json::Str("map".into())),
        ("tcoords", coords(false)),
        ("pcoords", coords(true)),
    ])
}

/// A hierarchical map request: an n-task chain onto n/2 ranks, 2 per node.
/// `variant` scales the edge weights, producing distinct-but-compatible
/// requests (same allocation and config: one batch group, different cache
/// keys).
fn hier_req_variant(n: usize, variant: usize) -> Json {
    let w = 1.0 + variant as f64 * 0.25;
    let tcoords = Json::Arr(
        (0..n)
            .map(|i| Json::Arr(vec![Json::Num(i as f64)]))
            .collect(),
    );
    let pcoords = Json::Arr(
        (0..n / 2)
            .map(|i| Json::Arr(vec![Json::Num((i / 2) as f64)]))
            .collect(),
    );
    let edges = Json::Arr(
        (0..n - 1)
            .map(|i| {
                Json::Arr(vec![
                    Json::Num(i as f64),
                    Json::Num((i + 1) as f64),
                    Json::Num(w),
                ])
            })
            .collect(),
    );
    Json::obj(vec![
        ("op", Json::Str("map".into())),
        ("tcoords", tcoords),
        ("pcoords", pcoords),
        ("edges", edges),
        (
            "hier",
            Json::obj(vec![
                ("ranks_per_node", Json::Num(2.0)),
                ("strategy", Json::Str("minvol".into())),
            ]),
        ),
    ])
}

fn hier_req(n: usize) -> Json {
    hier_req_variant(n, 0)
}

/// Pin `"cache":false` onto a map request (the rtt legs measure compute,
/// not the cache).
fn uncached(mut req: Json) -> Json {
    if let Json::Obj(m) = &mut req {
        m.insert("cache".to_string(), Json::Bool(false));
    }
    req
}

/// Flood a tiny pool (1 worker, 2 queue slots) with `burst`-sized waves of
/// concurrent one-shot connections and report throughput plus the shed
/// fraction — the service must answer (serve or shed) every connection
/// promptly instead of queueing without bound.
fn saturation(rec: &mut BenchRecorder, suffix: &str, burst: usize, waves: usize) {
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 1,
            queue_capacity: 2,
            retry_after_ms: 10,
            ..ServiceConfig::default()
        },
    )
    .expect("bind");
    let addr = svc.addr;
    let served = Arc::new(AtomicUsize::new(0));
    let shed = Arc::new(AtomicUsize::new(0));
    let start = Instant::now();
    for _ in 0..waves {
        let barrier = Arc::new(Barrier::new(burst));
        let handles: Vec<_> = (0..burst)
            .map(|_| {
                let barrier = Arc::clone(&barrier);
                let served = Arc::clone(&served);
                let shed = Arc::clone(&shed);
                std::thread::spawn(move || {
                    barrier.wait();
                    let mut client = match Client::connect(addr) {
                        Ok(c) => c,
                        Err(_) => return,
                    };
                    match client.request(&ping_req()) {
                        Ok(resp) if resp.get("ok") == Some(&Json::Bool(true)) => {
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(resp) if error_kind(&resp) == Some(ErrorKind::Overloaded) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        // A shed refusal can race the closed socket's TCP
                        // reset; the server-side counter still has it.
                        _ => {}
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
    let elapsed = start.elapsed();
    let total = burst * waves;
    let served = served.load(Ordering::Relaxed);
    let shed_client = shed.load(Ordering::Relaxed);
    let stats = svc.stats();
    let shed_server = stats.get("shed").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let answered_per_s = total as f64 / elapsed.as_secs_f64();
    let shed_frac = shed_server / total as f64;
    println!(
        "saturation{suffix}: {total} conns in {:.3}s ({answered_per_s:.0} answered/s), \
         {served} served, {shed_server} shed server-side ({shed_client} shed replies read)",
        elapsed.as_secs_f64()
    );
    rec.record_scalar(
        &format!("service/saturation{suffix}/answered_per_s"),
        "rate",
        answered_per_s,
    );
    rec.record_scalar(
        &format!("service/saturation{suffix}/shed_fraction"),
        "fraction",
        shed_frac,
    );
    svc.stop();
}

/// Cold vs hot round trips for one hierarchical request: cold opts out of
/// the cache every iteration (full recompute), hot repeats the identical
/// request against the default cache (one miss, then lookup + clone). The
/// speedup ratio and the reconciling hit/miss counters are recorded.
fn cache_legs(rec: &mut BenchRecorder, suffix: &str, n: usize) {
    let svc = Service::start("127.0.0.1:0").expect("bind");
    let mut client = Client::connect(svc.addr).expect("connect");
    let cold_req = uncached(hier_req(n));
    let r_cold = bench_quick(&format!("service/cache/cold/tasks={n}{suffix}"), || {
        client.request(&cold_req).expect("cold hier map")
    });
    rec.record(&r_cold, &[("tasks", n as f64)]);
    let hot_req = hier_req(n);
    let r_hot = bench_quick(&format!("service/cache/hot/tasks={n}{suffix}"), || {
        client.request(&hot_req).expect("hot hier map")
    });
    rec.record(&r_hot, &[("tasks", n as f64)]);
    let speedup = r_cold.per_iter_ns() / r_hot.per_iter_ns();
    rec.record_scalar(
        &format!("service/cache/speedup/tasks={n}{suffix}"),
        "ratio",
        speedup,
    );
    let stats = svc.stats();
    let cache = stats.get("cache").expect("cache section");
    let field = |k: &str| cache.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let (hits, misses, bypass) = (field("hits"), field("misses"), field("bypass"));
    println!(
        "cache{suffix}: tasks={n} cold {:.1}us hot {:.1}us speedup {speedup:.1}x \
         (hits {hits}, misses {misses}, bypass {bypass})",
        r_cold.per_iter_ns() / 1e3,
        r_hot.per_iter_ns() / 1e3,
    );
    rec.record_scalar(&format!("service/cache/hits{suffix}"), "count", hits);
    rec.record_scalar(&format!("service/cache/misses{suffix}"), "count", misses);
    svc.stop();
}

/// Throughput of `jobs x waves` distinct-but-compatible hierarchical
/// requests fired concurrently per wave.
fn compatible_wave_throughput(
    addr: std::net::SocketAddr,
    jobs: usize,
    waves: usize,
    tasks: usize,
) -> f64 {
    let start = Instant::now();
    for w in 0..waves {
        let barrier = Arc::new(Barrier::new(jobs));
        let handles: Vec<_> = (0..jobs)
            .map(|j| {
                let barrier = Arc::clone(&barrier);
                let req = hier_req_variant(tasks, w * jobs + j);
                std::thread::spawn(move || {
                    barrier.wait();
                    let resp = Client::connect(addr)
                        .expect("connect")
                        .request(&req)
                        .expect("batched hier map");
                    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
    (jobs * waves) as f64 / start.elapsed().as_secs_f64()
}

/// Batching throughput: the same compatible-request workload against a
/// plain service and against one with a short batch window, plus the
/// coalescing counters (`flushes + coalesced == jobs` must reconcile).
fn batch_legs(rec: &mut BenchRecorder, suffix: &str, jobs: usize, waves: usize, tasks: usize) {
    let solo = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            cache_capacity: 0,
            ..ServiceConfig::default()
        },
    )
    .expect("bind");
    let solo_rate = compatible_wave_throughput(solo.addr, jobs, waves, tasks);
    solo.stop();
    let batched = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            cache_capacity: 0,
            batch_window: std::time::Duration::from_millis(4),
            ..ServiceConfig::default()
        },
    )
    .expect("bind");
    let batched_rate = compatible_wave_throughput(batched.addr, jobs, waves, tasks);
    let stats = batched.stats();
    let b = stats.get("batch").expect("batch section");
    let field = |k: &str| b.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let (njobs, flushes, coalesced) = (field("jobs"), field("flushes"), field("coalesced"));
    assert_eq!(flushes + coalesced, njobs, "{stats:?}");
    println!(
        "batch{suffix}: {jobs}x{waves} tasks={tasks}: solo {solo_rate:.0}/s, \
         batched {batched_rate:.0}/s ({coalesced} of {njobs} jobs coalesced in {flushes} flushes)"
    );
    rec.record_scalar(
        &format!("service/batch/unbatched/answered_per_s{suffix}"),
        "rate",
        solo_rate,
    );
    rec.record_scalar(
        &format!("service/batch/batched/answered_per_s{suffix}"),
        "rate",
        batched_rate,
    );
    rec.record_scalar(
        &format!("service/batch/coalesced_fraction{suffix}"),
        "fraction",
        if njobs > 0.0 { coalesced / njobs } else { 0.0 },
    );
    batched.stop();
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let suffix = if smoke { "/smoke" } else { "" };
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    println!("== mapping service (bounded pool) ==");

    // Round-trip latency on a persistent connection against a
    // default-sized pool. `"cache":false` keeps these legs on the compute
    // path now that the service caches map replies by default.
    let svc = Service::start("127.0.0.1:0").expect("bind");
    let mut client = Client::connect(svc.addr).expect("connect");
    let ping = ping_req();
    let r = bench_quick(&format!("service/rtt/ping{suffix}"), || {
        client.request(&ping).expect("ping")
    });
    rec.record(&r, &[]);
    let n = if smoke { 64 } else { 512 };
    let req = uncached(map_req(n));
    let r = bench_quick(&format!("service/rtt/map/tasks={n}{suffix}"), || {
        client.request(&req).expect("map")
    });
    rec.record(&r, &[("tasks", n as f64)]);
    let req = uncached(hier_req(n));
    let r = bench_quick(&format!("service/rtt/hier/tasks={n}{suffix}"), || {
        client.request(&req).expect("hier map")
    });
    rec.record(&r, &[("tasks", n as f64)]);
    svc.stop();

    // Result cache: cold vs hot, and the hit/miss ledger.
    cache_legs(&mut rec, suffix, n);

    // Batching: compatible-request throughput with and without a window.
    let (jobs, bwaves) = if smoke { (4, 3) } else { (8, 8) };
    batch_legs(&mut rec, suffix, jobs, bwaves, n);

    // Saturation: overload must be answered, not buffered.
    let (burst, waves) = if smoke { (16, 4) } else { (48, 16) };
    saturation(&mut rec, suffix, burst, waves);

    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
