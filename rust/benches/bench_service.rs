//! Bench: the hardened mapping service — request round-trip latency over
//! TCP (ping / flat map / hierarchical map), and a saturation smoke test
//! that floods a deliberately tiny pool and reports sustained throughput,
//! shed fraction, and the time-to-shed (how fast overload is answered).
//! Results append to `BENCH_mapping.json` (override with
//! `TASKMAP_BENCH_OUT`).
//!
//! `--smoke` runs a miniature configuration (seconds, CI-sized) whose
//! entries are recorded under `.../smoke` names so they never clobber the
//! full trajectory rows.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use taskmap::coordinator::service::{error_kind, Client, ErrorKind, Service, ServiceConfig};
use taskmap::testutil::bench::{bench_quick, BenchRecorder};
use taskmap::testutil::json::Json;

fn ping_req() -> Json {
    Json::obj(vec![("op", Json::Str("ping".into()))])
}

/// A flat map request over an n-task 1D line (tasks ascending, procs
/// descending — forces real partitioning work, trivially checkable).
fn map_req(n: usize) -> Json {
    let coords = |rev: bool| {
        Json::Arr(
            (0..n)
                .map(|i| {
                    let x = if rev { n - 1 - i } else { i } as f64;
                    Json::Arr(vec![Json::Num(x)])
                })
                .collect(),
        )
    };
    Json::obj(vec![
        ("op", Json::Str("map".into())),
        ("tcoords", coords(false)),
        ("pcoords", coords(true)),
    ])
}

/// A hierarchical map request: an n-task chain onto n/2 ranks, 2 per node.
fn hier_req(n: usize) -> Json {
    let tcoords = Json::Arr(
        (0..n)
            .map(|i| Json::Arr(vec![Json::Num(i as f64)]))
            .collect(),
    );
    let pcoords = Json::Arr(
        (0..n / 2)
            .map(|i| Json::Arr(vec![Json::Num((i / 2) as f64)]))
            .collect(),
    );
    let edges = Json::Arr(
        (0..n - 1)
            .map(|i| Json::Arr(vec![Json::Num(i as f64), Json::Num((i + 1) as f64)]))
            .collect(),
    );
    Json::obj(vec![
        ("op", Json::Str("map".into())),
        ("tcoords", tcoords),
        ("pcoords", pcoords),
        ("edges", edges),
        (
            "hier",
            Json::obj(vec![
                ("ranks_per_node", Json::Num(2.0)),
                ("strategy", Json::Str("minvol".into())),
            ]),
        ),
    ])
}

/// Flood a tiny pool (1 worker, 2 queue slots) with `burst`-sized waves of
/// concurrent one-shot connections and report throughput plus the shed
/// fraction — the service must answer (serve or shed) every connection
/// promptly instead of queueing without bound.
fn saturation(rec: &mut BenchRecorder, suffix: &str, burst: usize, waves: usize) {
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 1,
            queue_capacity: 2,
            retry_after_ms: 10,
            ..ServiceConfig::default()
        },
    )
    .expect("bind");
    let addr = svc.addr;
    let served = Arc::new(AtomicUsize::new(0));
    let shed = Arc::new(AtomicUsize::new(0));
    let start = Instant::now();
    for _ in 0..waves {
        let barrier = Arc::new(Barrier::new(burst));
        let handles: Vec<_> = (0..burst)
            .map(|_| {
                let barrier = Arc::clone(&barrier);
                let served = Arc::clone(&served);
                let shed = Arc::clone(&shed);
                std::thread::spawn(move || {
                    barrier.wait();
                    let mut client = match Client::connect(addr) {
                        Ok(c) => c,
                        Err(_) => return,
                    };
                    match client.request(&ping_req()) {
                        Ok(resp) if resp.get("ok") == Some(&Json::Bool(true)) => {
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(resp) if error_kind(&resp) == Some(ErrorKind::Overloaded) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        // A shed refusal can race the closed socket's TCP
                        // reset; the server-side counter still has it.
                        _ => {}
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
    let elapsed = start.elapsed();
    let total = burst * waves;
    let served = served.load(Ordering::Relaxed);
    let shed_client = shed.load(Ordering::Relaxed);
    let stats = svc.stats();
    let shed_server = stats.get("shed").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let answered_per_s = total as f64 / elapsed.as_secs_f64();
    let shed_frac = shed_server / total as f64;
    println!(
        "saturation{suffix}: {total} conns in {:.3}s ({answered_per_s:.0} answered/s), \
         {served} served, {shed_server} shed server-side ({shed_client} shed replies read)",
        elapsed.as_secs_f64()
    );
    rec.record_scalar(
        &format!("service/saturation{suffix}/answered_per_s"),
        "rate",
        answered_per_s,
    );
    rec.record_scalar(
        &format!("service/saturation{suffix}/shed_fraction"),
        "fraction",
        shed_frac,
    );
    svc.stop();
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let suffix = if smoke { "/smoke" } else { "" };
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    println!("== mapping service (bounded pool) ==");

    // Round-trip latency on a persistent connection against a
    // default-sized pool.
    let svc = Service::start("127.0.0.1:0").expect("bind");
    let mut client = Client::connect(svc.addr).expect("connect");
    let ping = ping_req();
    let r = bench_quick(&format!("service/rtt/ping{suffix}"), || {
        client.request(&ping).expect("ping")
    });
    rec.record(&r, &[]);
    let n = if smoke { 64 } else { 512 };
    let req = map_req(n);
    let r = bench_quick(&format!("service/rtt/map/tasks={n}{suffix}"), || {
        client.request(&req).expect("map")
    });
    rec.record(&r, &[("tasks", n as f64)]);
    let req = hier_req(n);
    let r = bench_quick(&format!("service/rtt/hier/tasks={n}{suffix}"), || {
        client.request(&req).expect("hier map")
    });
    rec.record(&r, &[("tasks", n as f64)]);
    svc.stop();

    // Saturation: overload must be answered, not buffered.
    let (burst, waves) = if smoke { (16, 4) } else { (48, 16) };
    saturation(&mut rec, suffix, burst, waves);

    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
