//! Bench: the pluggable objective layer — WeightedHops-vs-MaxLinkLoad
//! quality ratios, congestion-objective mapper wall time across thread
//! budgets, the blended (MaxLinkLoad × NUMA) depth-3 path's thread
//! scaling and quality, and the unrolled `whops_row` kernel
//! microbenchmark. Results
//! append to `BENCH_mapping.json` (override with `TASKMAP_BENCH_OUT`) so
//! the trajectory is diffable across commits.
//!
//! `--smoke` runs a miniature configuration (seconds, CI-sized) whose
//! entries are recorded under `.../smoke` names so they never clobber the
//! full trajectory rows.

use taskmap::apps::minighost::MiniGhost;
use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use taskmap::machine::{cray_xk7, NumaTopology, SparseAllocator};
use taskmap::mapping::rotations::NativeBackend;
use taskmap::metrics::eval_full;
use taskmap::metrics::native::batched_weighted_hops_native;
use taskmap::objective::ObjectiveKind;
use taskmap::testutil::bench::{bench, bench_quick, BenchRecorder};

const ROT: usize = 12;

fn hier_cfg(threads: usize, objective: ObjectiveKind) -> HierConfig {
    let mut cfg = HierConfig {
        intra: IntraNodeStrategy::MinVolume { passes: 4 },
        max_rotations: ROT,
        ..HierConfig::default()
    };
    cfg.spec.threads = threads;
    cfg.spec.objective = objective;
    cfg
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    let suffix = if smoke { "/smoke" } else { "" };
    println!("== objective layer ==");

    // MiniGhost preset on the XK7 model.
    let tdims = if smoke {
        [4usize, 4, 4]
    } else {
        [16usize, 16, 8]
    };
    let rpn = 16usize;
    let mg = MiniGhost::weak_scaling(tdims);
    let graph = mg.graph();
    let alloc = SparseAllocator {
        machine: cray_xk7(&[10, 8, 10]),
        nodes_per_router: 2,
        ranks_per_node: rpn,
        occupancy: 0.4,
    }
    .allocate(mg.num_tasks() / rpn, 42);

    // Quality: the same hierarchical mapper under each objective, judged on
    // both metrics. maxload/whops WH-ratio > 1 and Lat-ratio < 1 is the
    // expected trade.
    let mut results = Vec::new();
    for kind in ObjectiveKind::ALL {
        let m = map_hierarchical(&graph, &graph.coords, &alloc, &hier_cfg(0, kind), &NativeBackend);
        let full = eval_full(&graph, &m.task_to_rank, &alloc);
        let lat = full.link.as_ref().unwrap().max_latency;
        results.push((kind, full.weighted_hops, lat));
    }
    let (_, wh0, lat0) = results[0];
    for &(kind, wh, lat) in &results[1..] {
        let (wh_ratio, lat_ratio) = (wh / wh0, lat / lat0);
        println!(
            "hier {}/whops: WeightedHops {wh_ratio:.3}, MaxLinkLatency {lat_ratio:.3}",
            kind.name()
        );
        rec.record_scalar(
            &format!("objective/{}{suffix}/whops_vs_whops_obj", kind.name()),
            "ratio",
            wh_ratio,
        );
        rec.record_scalar(
            &format!("objective/{}{suffix}/maxlat_vs_whops_obj", kind.name()),
            "ratio",
            lat_ratio,
        );
    }

    // Thread scaling of the congestion-objective mapper (sweep + routed
    // scoring + incremental MinVolume refinement).
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    for &threads in thread_counts {
        let cfg = hier_cfg(threads, ObjectiveKind::MaxLinkLoad);
        let name = format!(
            "objective_map/maxload/tasks={}/threads={threads}{suffix}",
            mg.num_tasks()
        );
        let result = bench_quick(&name, || {
            map_hierarchical(&graph, &graph.coords, &alloc, &cfg, &NativeBackend)
        });
        rec.record(&result, &[("threads", threads as f64)]);
    }

    // Blended (MaxLinkLoad x NUMA) depth-3 path: the unified evaluator's
    // routed network term plus the socket intra-node term, end to end
    // through the three-level mapper — thread scaling plus quality vs the
    // plain maxload run.
    let topo = NumaTopology::xk7();
    for &threads in thread_counts {
        let mut cfg = hier_cfg(threads, ObjectiveKind::MaxLinkLoad);
        cfg.spec.numa = Some(topo);
        let name = format!(
            "objective_map/maxload_numa/tasks={}/threads={threads}{suffix}",
            mg.num_tasks()
        );
        let result = bench_quick(&name, || {
            map_hierarchical(&graph, &graph.coords, &alloc, &cfg, &NativeBackend)
        });
        rec.record(&result, &[("threads", threads as f64)]);
    }
    {
        let plain = map_hierarchical(
            &graph,
            &graph.coords,
            &alloc,
            &hier_cfg(0, ObjectiveKind::MaxLinkLoad),
            &NativeBackend,
        );
        let mut blended_cfg = hier_cfg(0, ObjectiveKind::MaxLinkLoad);
        blended_cfg.spec.numa = Some(topo);
        let blended = map_hierarchical(&graph, &graph.coords, &alloc, &blended_cfg, &NativeBackend);
        let lat = |m: &[u32]| eval_full(&graph, m, &alloc).link.unwrap().max_latency;
        let (lp, lb) = (lat(&plain.task_to_rank), lat(&blended.task_to_rank));
        let lat_ratio = if lp > 0.0 { lb / lp } else { 1.0 };
        println!("hier maxload+numa/maxload: MaxLinkLatency {lat_ratio:.3}");
        rec.record_scalar(
            &format!("objective/maxload_numa{suffix}/maxlat_vs_maxload"),
            "ratio",
            lat_ratio,
        );
    }

    // The unrolled whops_row kernel (manual 8-lane accumulators): ns/iter
    // here is the before/after trajectory for the SIMD roadmap item.
    let (r, e, d) = if smoke {
        (2usize, 4096usize, 3usize)
    } else {
        (4usize, 65536usize, 3usize)
    };
    let src: Vec<f32> = (0..r * e * d).map(|k| ((k * 7) % 13) as f32).collect();
    let dst: Vec<f32> = (0..r * e * d).map(|k| ((k * 5) % 13) as f32).collect();
    let w: Vec<f32> = (0..e).map(|k| 0.5 + (k % 3) as f32).collect();
    let dims = vec![13.0f32; d];
    let wrap = vec![1.0f32, 0.0, 1.0];
    let name = format!("whops_row/unrolled/r={r}/e={e}/d={d}{suffix}");
    let result = bench(&name, || {
        batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, r, e, d)
    });
    rec.record(&result, &[("edges", (r * e) as f64)]);

    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
