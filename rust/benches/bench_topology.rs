//! Bench: the non-torus topologies behind the [`Topology`] trait — the
//! hierarchical mapper end to end on a fat-tree and a dragonfly.
//!
//! Each case maps a 3D stencil task graph onto a dense allocation (one
//! rank per router) of the target network: wall-time rows across thread
//! budgets plus a quality row (mapped / default-order WeightedHops, < 1.0
//! means the geometric sweep beat the identity placement under that
//! network's own distance model). Results append to `BENCH_mapping.json`
//! under `topology/...` (override the path with `TASKMAP_BENCH_OUT`).
//!
//! `--smoke` runs miniature cases recorded under `.../smoke` names so they
//! never clobber the full trajectory rows.

use taskmap::apps::stencil::stencil_graph;
use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use taskmap::machine::{Allocation, Dragonfly, FatTree, Network, Topology};
use taskmap::mapping::rotations::NativeBackend;
use taskmap::metrics::eval_hops;
use taskmap::testutil::bench::{bench_quick, BenchRecorder};

/// Dense bijective allocation: one node of one rank per router.
fn dense_alloc(machine: Network) -> Allocation {
    let n = machine.num_routers();
    Allocation {
        machine,
        core_router: (0..n as u32).collect(),
        core_node: (0..n as u32).collect(),
        ranks_per_node: 1,
    }
}

fn run_case(
    rec: &mut BenchRecorder,
    tag: &str,
    suffix: &str,
    thread_counts: &[usize],
    tdims: &[usize; 3],
    machine: Network,
) {
    let g = stencil_graph(tdims, false, 1.0);
    let alloc = dense_alloc(machine);
    assert_eq!(alloc.num_ranks(), g.num_tasks, "case must be a bijection");
    for &threads in thread_counts {
        let mut cfg = HierConfig {
            intra: IntraNodeStrategy::MinVolume { passes: 2 },
            max_rotations: 8,
            ..HierConfig::default()
        };
        cfg.spec.threads = threads;
        let name = format!(
            "topology/{tag}/tasks={}/threads={threads}{suffix}",
            g.num_tasks
        );
        let result = bench_quick(&name, || {
            map_hierarchical(&g, &g.coords, &alloc, &cfg, &NativeBackend)
        });
        rec.record(&result, &[("threads", threads as f64)]);
    }
    let mut cfg = HierConfig {
        intra: IntraNodeStrategy::MinVolume { passes: 2 },
        max_rotations: 8,
        ..HierConfig::default()
    };
    cfg.spec.threads = 1;
    let mapped = map_hierarchical(&g, &g.coords, &alloc, &cfg, &NativeBackend);
    let identity: Vec<u32> = (0..g.num_tasks as u32).collect();
    let wh_mapped = eval_hops(&g, &mapped.task_to_rank, &alloc).weighted_hops;
    let wh_default = eval_hops(&g, &identity, &alloc).weighted_hops;
    let ratio = if wh_default > 0.0 {
        wh_mapped / wh_default
    } else {
        1.0
    };
    println!("{tag}: mapped/default WeightedHops {ratio:.4} ({wh_mapped:.0}/{wh_default:.0})");
    rec.record_scalar(
        &format!("topology/{tag}/quality{suffix}"),
        "mapped_over_default_whops",
        ratio,
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    let suffix = if smoke { "/smoke" } else { "" };
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    println!("== non-torus topologies (fat-tree / dragonfly) ==");

    // Fat-tree: radix-4 leaves match the stencil task count exactly.
    let (ft, ft_dims): (FatTree, [usize; 3]) = if smoke {
        (FatTree::new(3, 4), [4, 4, 4]) // 64 leaves
    } else {
        (FatTree::new(5, 4), [16, 8, 8]) // 1024 leaves
    };
    run_case(
        &mut rec,
        "fattree",
        suffix,
        thread_counts,
        &ft_dims,
        ft.into(),
    );

    // Dragonfly: groups x routers/group bijective with the same graphs.
    let (df, df_dims): (Dragonfly, [usize; 3]) = if smoke {
        (Dragonfly::new(8, 8, 1), [4, 4, 4]) // 64 routers
    } else {
        (Dragonfly::new(32, 32, 1), [16, 8, 8]) // 1024 routers
    };
    run_case(
        &mut rec,
        "dragonfly",
        suffix,
        thread_counts,
        &df_dims,
        df.into(),
    );

    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
