//! Bench: regenerate Figs 10-12 (HOMME on Titan, sparse allocations).
//! Small scale by default; `--full` for the 86,400-element / 86K-proc runs.

use taskmap::coordinator::{experiments, Ctx};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let ctx = Ctx::new(full, 42, false);
    eprintln!("backend: {}", ctx.backend_name());
    for id in ["fig10", "fig11", "fig12"] {
        let t0 = std::time::Instant::now();
        for t in experiments::run(id, &ctx).unwrap() {
            println!("{}", t.markdown());
        }
        println!("[{id}] regenerated in {:.1}s\n", t0.elapsed().as_secs_f64());
    }
}
