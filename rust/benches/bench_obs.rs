//! Bench: tracing overhead. Runs the hierarchical mapper with the obs
//! recorder off, globally on (ring + metrics), and on with a JSONL sink
//! installed, and records the per-iteration wall time plus the on/off
//! overhead ratio in `BENCH_mapping.json` (override with
//! `TASKMAP_BENCH_OUT`). The ratio is the number the "one branch when
//! off, cheap when on" design claim lives or dies by.
//!
//! `--smoke` runs a miniature configuration (seconds, CI-sized) recorded
//! under `.../smoke` names so it never clobbers the full trajectory rows.

use taskmap::apps::minighost::MiniGhost;
use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use taskmap::machine::{cray_xk7, SparseAllocator};
use taskmap::mapping::rotations::NativeBackend;
use taskmap::obs;
use taskmap::testutil::bench::{bench_quick, BenchRecorder};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    println!("== obs recorder overhead ==");
    let suffix = if smoke { "/smoke" } else { "" };

    let tdims = if smoke { [4usize, 4, 4] } else { [16usize, 16, 8] };
    let rpn = 16;
    let mg = MiniGhost::weak_scaling(tdims);
    let graph = mg.graph();
    let alloc = SparseAllocator {
        machine: cray_xk7(&[10, 8, 10]),
        nodes_per_router: 2,
        ranks_per_node: rpn,
        occupancy: 0.4,
    }
    .allocate(mg.num_tasks() / rpn, 42);
    let mut cfg = HierConfig {
        intra: IntraNodeStrategy::MinVolume { passes: 4 },
        max_rotations: if smoke { 4 } else { 12 },
        ..HierConfig::default()
    };
    cfg.spec.threads = 2;
    let tasks = mg.num_tasks();
    let mut run = || map_hierarchical(&graph, &graph.coords, &alloc, &cfg, &NativeBackend);

    // Recorder compiled in but disabled: the baseline every pipeline
    // caller pays (one relaxed load + TLS read per instrumentation site).
    obs::set_enabled(false);
    let off = bench_quick(&format!("obs/off/tasks={tasks}{suffix}"), &mut run);
    rec.record(&off, &[("tracing", 0.0)]);

    // Recorder on: events flow to the bounded ring and the metrics
    // registry, no I/O.
    obs::set_enabled(true);
    let on = bench_quick(&format!("obs/on/tasks={tasks}{suffix}"), &mut run);
    rec.record(&on, &[("tracing", 1.0)]);

    // Recorder on with a JSONL sink: adds serialization + buffered file
    // writes per lane flush.
    let sink_path = std::env::temp_dir().join(format!("taskmap_bench_obs_{}.jsonl", std::process::id()));
    let sink_ok = obs::trace::install_sink(sink_path.to_str().expect("temp path is utf-8")).is_ok();
    if sink_ok {
        let sunk = bench_quick(&format!("obs/on+sink/tasks={tasks}{suffix}"), &mut run);
        rec.record(&sunk, &[("tracing", 1.0)]);
        let sink_ratio = sunk.per_iter_ns() / off.per_iter_ns();
        println!("tracing+sink overhead: {sink_ratio:.3}x");
        rec.record_scalar(&format!("obs/sink_overhead{suffix}"), "ratio", sink_ratio);
    }
    obs::trace::clear_sink();
    obs::set_enabled(false);
    let _ = std::fs::remove_file(&sink_path);

    let ratio = on.per_iter_ns() / off.per_iter_ns();
    println!("tracing overhead: {ratio:.3}x");
    rec.record_scalar(&format!("obs/overhead{suffix}"), "ratio", ratio);

    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
