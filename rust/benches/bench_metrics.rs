//! Microbenchmarks: metric evaluation (hop counting, routing + link
//! accumulation) — the other L3 hot path.

use taskmap::apps::minighost::MiniGhost;
use taskmap::machine::{cray_xk7, SparseAllocator};
use taskmap::metrics::{eval_full, eval_hops};
use taskmap::testutil::bench::bench;

fn main() {
    println!("== metrics engine ==");
    for (procs, dims) in [(4_096usize, [16usize, 16, 16]), (32_768, [32, 32, 32])] {
        let mg = MiniGhost::weak_scaling(dims);
        let graph = mg.graph();
        let allocator = SparseAllocator {
            machine: cray_xk7(&[16, 12, 16]),
            nodes_per_router: 2,
            ranks_per_node: 16,
            occupancy: 0.3,
        };
        let alloc = allocator.allocate(procs / 16, 42);
        let mapping = mg.default_order();
        bench(
            &format!("eval_hops   minighost procs={procs} edges={}", graph.edges.len()),
            || eval_hops(&graph, &mapping, &alloc),
        );
        bench(
            &format!("eval_full   minighost procs={procs} edges={}", graph.edges.len()),
            || eval_full(&graph, &mapping, &alloc),
        );
    }
}
