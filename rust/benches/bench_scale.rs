//! Scale bench: the multilevel V-cycle (`HierConfig::coarsen`) against
//! the direct rotation sweep on MiniGhost-style weak-scaling graphs.
//!
//! Each case maps a 3D stencil task graph onto a dense torus allocation
//! (every router one node, 16 ranks per node) twice — once through the
//! V-cycle, once directly — and records single-shot wall times plus the
//! inter-node WeightedHops quality ratio into `BENCH_mapping.json`
//! (`scale/...` rows). The direct sweep is skipped above
//! `DIRECT_CAP` tasks (that is the regime the V-cycle exists for);
//! skipped comparisons are reported, never silently dropped.
//!
//! Modes: `--smoke` (one 4K-task case, CI-sized), default (32K + 110K),
//! `--full` (adds the million-task case).

use std::time::Instant;
use taskmap::apps::minighost::MiniGhost;
use taskmap::coarsen::CoarsenConfig;
use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use taskmap::machine::{Allocation, Torus};
use taskmap::mapping::rotations::NativeBackend;
use taskmap::metrics::eval_hops;
use taskmap::testutil::bench::BenchRecorder;

const RANKS_PER_NODE: usize = 16;

/// Largest task count the direct sweep is still timed at; beyond this the
/// baseline would dominate the bench wall-clock for no extra signal.
const DIRECT_CAP: usize = 200_000;

/// Dense allocation: every router of the `sizes` torus is one node of
/// `RANKS_PER_NODE` consecutive ranks.
fn dense_alloc(sizes: &[usize]) -> Allocation {
    let torus = Torus::torus(sizes);
    let nn: usize = sizes.iter().product();
    let mut core_router = Vec::with_capacity(nn * RANKS_PER_NODE);
    let mut core_node = Vec::with_capacity(nn * RANKS_PER_NODE);
    for node in 0..nn {
        for _ in 0..RANKS_PER_NODE {
            core_router.push(node as u32);
            core_node.push(node as u32);
        }
    }
    Allocation {
        machine: torus.into(),
        core_router,
        core_node,
        ranks_per_node: RANKS_PER_NODE,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let full = std::env::args().any(|a| a == "--full");
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    let prefix = if smoke { "scale/smoke" } else { "scale" };
    // (task dims, node grid, coarsen target): tasks = product(tdims),
    // ranks = product(nodes) * 16 = tasks, so every case is a bijection.
    // The smoke case lowers the target so 4K tasks still exercise a real
    // hierarchy (default 4096 would swallow the whole graph).
    let cases: Vec<([usize; 3], [usize; 3], usize)> = if smoke {
        vec![([16, 16, 16], [8, 8, 4], 512)]
    } else {
        let mut v = vec![
            ([32, 32, 32], [16, 16, 8], 4096),
            ([48, 48, 48], [24, 24, 12], 4096),
        ];
        if full {
            v.push(([100, 100, 100], [50, 50, 25], 4096));
        }
        v
    };
    println!("== V-cycle vs direct sweep (MiniGhost weak scaling) ==");
    for (tdims, nodes, target) in cases {
        let g = MiniGhost::weak_scaling(tdims).graph();
        let n = g.num_tasks;
        let alloc = dense_alloc(&nodes);
        assert_eq!(alloc.num_ranks(), n, "case must be a bijection");
        let base = HierConfig {
            intra: IntraNodeStrategy::MinVolume { passes: 2 },
            max_rotations: 4,
            ..HierConfig::default()
        };
        let mut vcfg = base.clone();
        vcfg.spec.coarsen = Some(CoarsenConfig {
            target_tasks: target,
            ..CoarsenConfig::default()
        });
        let t0 = Instant::now();
        let vm = map_hierarchical(&g, &g.coords, &alloc, &vcfg, &NativeBackend);
        let v_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert!(
            !vm.coarsen_levels.is_empty(),
            "tasks={n}: expected the V-cycle path"
        );
        let v_whops = eval_hops(&g, &vm.task_to_rank, &alloc).weighted_hops;
        println!(
            "tasks={n:>9}  vcycle {v_ms:>10.1} ms  levels {:?}",
            vm.coarsen_levels
        );
        rec.record_scalar(&format!("{prefix}/tasks={n}/vcycle"), "wall_ms", v_ms);
        rec.record_scalar(
            &format!("{prefix}/tasks={n}/vcycle_whops"),
            "weighted_hops",
            v_whops,
        );
        if n > DIRECT_CAP {
            println!("tasks={n:>9}  direct skipped (over the {DIRECT_CAP}-task baseline cap)");
            continue;
        }
        let t0 = Instant::now();
        let dm = map_hierarchical(&g, &g.coords, &alloc, &base, &NativeBackend);
        let d_ms = t0.elapsed().as_secs_f64() * 1e3;
        let d_whops = eval_hops(&g, &dm.task_to_rank, &alloc).weighted_hops;
        let speedup = d_ms / v_ms.max(1e-9);
        let quality = v_whops / d_whops.max(1e-9);
        println!(
            "tasks={n:>9}  direct {d_ms:>10.1} ms  speedup {speedup:>6.2}x  \
             quality ratio {quality:.4} (vcycle/direct weighted hops)"
        );
        rec.record_scalar(&format!("{prefix}/tasks={n}/direct"), "wall_ms", d_ms);
        rec.record_scalar(&format!("{prefix}/tasks={n}/speedup"), "x", speedup);
        rec.record_scalar(
            &format!("{prefix}/tasks={n}/quality_ratio"),
            "vcycle_over_direct",
            quality,
        );
    }
    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
