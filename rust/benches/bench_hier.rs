//! Bench: the hierarchical node→core mapper — wall time across thread
//! budgets, plus the hierarchical-vs-flat quality comparison on the
//! MiniGhost and HOMME presets. Results append to `BENCH_mapping.json`
//! (override with `TASKMAP_BENCH_OUT`).
//!
//! `--smoke` runs a miniature configuration (seconds, CI-sized) whose
//! entries are recorded under `.../smoke` names so they never clobber the
//! full trajectory rows.

use taskmap::apps::homme::{Homme, HommeCoords};
use taskmap::apps::minighost::MiniGhost;
use taskmap::apps::TaskGraph;
use taskmap::geom::Coords;
use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use taskmap::machine::{cray_xk7, Allocation, SparseAllocator};
use taskmap::mapping::pipeline::{z2_map, Z2Config};
use taskmap::mapping::rotations::NativeBackend;
use taskmap::metrics::eval_full;
use taskmap::testutil::bench::{bench_quick, BenchRecorder};

const ROT: usize = 12;

fn allocator(ranks_per_node: usize) -> SparseAllocator {
    SparseAllocator {
        machine: cray_xk7(&[10, 8, 10]),
        nodes_per_router: 2,
        ranks_per_node,
        occupancy: 0.4,
    }
}

fn hier_cfg(threads: usize) -> HierConfig {
    let mut cfg = HierConfig {
        intra: IntraNodeStrategy::MinVolume { passes: 4 },
        max_rotations: ROT,
        ..HierConfig::default()
    };
    cfg.spec.threads = threads;
    cfg
}

/// Record flat-vs-hier quality (WeightedHops and Data(M) ratios, hier/flat:
/// < 1.0 = the hierarchy wins) for one preset.
fn record_quality(
    rec: &mut BenchRecorder,
    tag: &str,
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
) {
    let mut flat_cfg = Z2Config::z2_1();
    flat_cfg.max_rotations = ROT;
    let flat = z2_map(graph, tcoords, alloc, &flat_cfg, &NativeBackend);
    let hier = map_hierarchical(graph, tcoords, alloc, &hier_cfg(0), &NativeBackend);
    let mf = eval_full(graph, &flat, alloc);
    let mh = eval_full(graph, &hier.task_to_rank, alloc);
    let (lf, lh) = (mf.link.unwrap(), mh.link.unwrap());
    let wh_ratio = mh.weighted_hops / mf.weighted_hops;
    let data_ratio = lh.max_data / lf.max_data;
    let swaps = hier.swaps_applied;
    println!(
        "{tag}: hier/flat WeightedHops {wh_ratio:.3}, Data(M) {data_ratio:.3}, {swaps} swaps"
    );
    rec.record_scalar(&format!("hier/{tag}/whops_vs_flat"), "ratio", wh_ratio);
    rec.record_scalar(&format!("hier/{tag}/maxdata_vs_flat"), "ratio", data_ratio);
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    println!("== hierarchical node-core mapper ==");
    let suffix = if smoke { "/smoke" } else { "" };
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };

    // MiniGhost preset.
    let (tdims, rpn) = if smoke {
        ([4usize, 4, 4], 16)
    } else {
        ([16usize, 16, 8], 16)
    };
    let mg = MiniGhost::weak_scaling(tdims);
    let graph = mg.graph();
    let alloc = allocator(rpn).allocate(mg.num_tasks() / rpn, 42);
    for &threads in thread_counts {
        let cfg = hier_cfg(threads);
        let name = format!(
            "hier_map/minighost/tasks={}/threads={threads}{suffix}",
            mg.num_tasks()
        );
        let result = bench_quick(&name, || {
            map_hierarchical(&graph, &graph.coords, &alloc, &cfg, &NativeBackend)
        });
        rec.record(&result, &[("threads", threads as f64)]);
    }
    record_quality(
        &mut rec,
        &format!("minighost{suffix}"),
        &graph,
        &graph.coords,
        &alloc,
    );

    // HOMME preset (one rank per element: bijective mapping).
    let ne = if smoke { 8 } else { 24 };
    let homme = Homme::new(ne);
    let graph = homme.graph();
    let tcoords = homme.coords(HommeCoords::Cube);
    let rpn = 16;
    let alloc = allocator(rpn).allocate(homme.num_tasks() / rpn, 42);
    for &threads in thread_counts {
        let cfg = hier_cfg(threads);
        let name = format!(
            "hier_map/homme/tasks={}/threads={threads}{suffix}",
            homme.num_tasks()
        );
        let result = bench_quick(&name, || {
            map_hierarchical(&graph, &tcoords, &alloc, &cfg, &NativeBackend)
        });
        rec.record(&result, &[("threads", threads as f64)]);
    }
    record_quality(&mut rec, &format!("homme{suffix}"), &graph, &tcoords, &alloc);

    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
