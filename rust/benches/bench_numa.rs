//! Bench: the depth-3 (node→socket→core) NUMA-aware mapper — wall time
//! across thread budgets, depth-3-vs-depth-2 quality under the XK7
//! Interlagos node model on the MiniGhost and HOMME presets, and the
//! **blended** (routed MaxLinkLoad × NUMA) depth-3 path: thread-scaling
//! rows plus blended-vs-WeightedHops quality (NumaAware value and routed
//! bottleneck ratios). Results append to `BENCH_mapping.json` (override
//! with `TASKMAP_BENCH_OUT`).
//!
//! `--smoke` runs a miniature configuration (seconds, CI-sized) whose
//! entries are recorded under `.../smoke` names so they never clobber the
//! full trajectory rows.

use taskmap::apps::homme::{Homme, HommeCoords};
use taskmap::apps::minighost::MiniGhost;
use taskmap::apps::TaskGraph;
use taskmap::geom::Coords;
use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use taskmap::machine::{cray_xk7, Allocation, NumaTopology, SparseAllocator};
use taskmap::mapping::rotations::NativeBackend;
use taskmap::metrics::eval_full;
use taskmap::objective::{eval_numa, ObjectiveKind};
use taskmap::testutil::bench::{bench_quick, BenchRecorder};

const ROT: usize = 12;

fn allocator(ranks_per_node: usize) -> SparseAllocator {
    SparseAllocator {
        machine: cray_xk7(&[10, 8, 10]),
        nodes_per_router: 2,
        ranks_per_node,
        occupancy: 0.4,
    }
}

fn cfg(threads: usize, numa: Option<NumaTopology>) -> HierConfig {
    let mut cfg = HierConfig {
        intra: IntraNodeStrategy::MinVolume { passes: 4 },
        max_rotations: ROT,
        ..HierConfig::default()
    };
    cfg.spec.threads = threads;
    cfg.spec.numa = numa;
    cfg
}

fn blended_cfg(threads: usize, topo: NumaTopology) -> HierConfig {
    let mut cfg = cfg(threads, Some(topo));
    cfg.spec.objective = ObjectiveKind::MaxLinkLoad;
    cfg
}

/// Record blended-vs-WeightedHops depth-3 quality: NumaAware-value and
/// routed-bottleneck ratios (blended/whops; Lat < 1.0 = the blended
/// evaluator bought bottleneck relief). `wh` is the depth-3 WeightedHops
/// mapping [`record_quality`] already computed.
#[allow(clippy::too_many_arguments)]
fn record_blended_quality(
    rec: &mut BenchRecorder,
    tag: &str,
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    topo: NumaTopology,
    wh: &taskmap::hier::HierMapping,
) {
    let bl = map_hierarchical(graph, tcoords, alloc, &blended_cfg(0, topo), &NativeBackend);
    let lat = |m: &taskmap::hier::HierMapping| {
        eval_full(graph, &m.task_to_rank, alloc)
            .link
            .expect("eval_full computes link metrics")
            .max_latency
    };
    let (vw, vb) = (
        eval_numa(graph, &wh.task_to_rank, alloc, &topo).value,
        eval_numa(graph, &bl.task_to_rank, alloc, &topo).value,
    );
    let (lw, lb) = (lat(wh), lat(&bl));
    let value_ratio = if vw > 0.0 { vb / vw } else { 1.0 };
    let lat_ratio = if lw > 0.0 { lb / lw } else { 1.0 };
    println!(
        "{tag}: blended/whops depth-3 NumaValue {value_ratio:.3}, MaxLinkLatency {lat_ratio:.3}"
    );
    rec.record_scalar(&format!("numa/{tag}/blended_value_vs_whops"), "ratio", value_ratio);
    rec.record_scalar(&format!("numa/{tag}/blended_maxlat_vs_whops"), "ratio", lat_ratio);
}

/// Record depth-3-vs-depth-2 quality under the NumaAware objective:
/// total-value and cross-socket-weight ratios (d3/d2, < 1.0 = depth 3
/// wins). Returns the depth-3 mapping so the blended comparison can
/// reuse it instead of recomputing the identical run.
fn record_quality(
    rec: &mut BenchRecorder,
    tag: &str,
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    topo: NumaTopology,
) -> taskmap::hier::HierMapping {
    let d2 = map_hierarchical(graph, tcoords, alloc, &cfg(0, None), &NativeBackend);
    let d3 = map_hierarchical(graph, tcoords, alloc, &cfg(0, Some(topo)), &NativeBackend);
    let m2 = eval_numa(graph, &d2.task_to_rank, alloc, &topo);
    let m3 = eval_numa(graph, &d3.task_to_rank, alloc, &topo);
    let value_ratio = if m2.value > 0.0 { m3.value / m2.value } else { 1.0 };
    let xsock_ratio = if m2.socket_weight > 0.0 {
        m3.socket_weight / m2.socket_weight
    } else {
        1.0
    };
    println!(
        "{tag}: depth3/depth2 NumaValue {value_ratio:.3}, cross-socket {xsock_ratio:.3}, \
         {} socket swaps",
        d3.socket_swaps
    );
    rec.record_scalar(&format!("numa/{tag}/value_vs_depth2"), "ratio", value_ratio);
    rec.record_scalar(&format!("numa/{tag}/xsock_vs_depth2"), "ratio", xsock_ratio);
    d3
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rec = BenchRecorder::open("BENCH_mapping.json");
    println!("== depth-3 NUMA-aware mapper ==");
    let suffix = if smoke { "/smoke" } else { "" };
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let topo = NumaTopology::xk7();
    let rpn = topo.ranks_per_node();

    // MiniGhost preset.
    let tdims = if smoke { [4usize, 4, 4] } else { [16usize, 16, 8] };
    let mg = MiniGhost::weak_scaling(tdims);
    let graph = mg.graph();
    let alloc = allocator(rpn).allocate(mg.num_tasks() / rpn, 42);
    for &threads in thread_counts {
        let c = cfg(threads, Some(topo));
        let name = format!(
            "numa_map/minighost/tasks={}/threads={threads}{suffix}",
            mg.num_tasks()
        );
        let result = bench_quick(&name, || {
            map_hierarchical(&graph, &graph.coords, &alloc, &c, &NativeBackend)
        });
        rec.record(&result, &[("threads", threads as f64)]);
    }
    let d3 = record_quality(
        &mut rec,
        &format!("minighost{suffix}"),
        &graph,
        &graph.coords,
        &alloc,
        topo,
    );
    // Blended (MaxLinkLoad x NUMA) depth-3 path: thread scaling + quality.
    for &threads in thread_counts {
        let c = blended_cfg(threads, topo);
        let name = format!(
            "numa_map_blended/minighost/tasks={}/threads={threads}{suffix}",
            mg.num_tasks()
        );
        let result = bench_quick(&name, || {
            map_hierarchical(&graph, &graph.coords, &alloc, &c, &NativeBackend)
        });
        rec.record(&result, &[("threads", threads as f64)]);
    }
    record_blended_quality(
        &mut rec,
        &format!("minighost{suffix}"),
        &graph,
        &graph.coords,
        &alloc,
        topo,
        &d3,
    );

    // HOMME preset (one rank per element: bijective mapping).
    let ne = if smoke { 8 } else { 24 };
    let homme = Homme::new(ne);
    let graph = homme.graph();
    let tcoords = homme.coords(HommeCoords::Cube);
    let alloc = allocator(rpn).allocate(homme.num_tasks() / rpn, 42);
    for &threads in thread_counts {
        let c = cfg(threads, Some(topo));
        let name = format!(
            "numa_map/homme/tasks={}/threads={threads}{suffix}",
            homme.num_tasks()
        );
        let result = bench_quick(&name, || {
            map_hierarchical(&graph, &tcoords, &alloc, &c, &NativeBackend)
        });
        rec.record(&result, &[("threads", threads as f64)]);
    }
    let d3 = record_quality(
        &mut rec,
        &format!("homme{suffix}"),
        &graph,
        &tcoords,
        &alloc,
        topo,
    );
    record_blended_quality(
        &mut rec,
        &format!("homme{suffix}"),
        &graph,
        &tcoords,
        &alloc,
        topo,
        &d3,
    );

    if let Err(e) = rec.write() {
        eprintln!("failed to write bench trajectory: {e}");
    }
}
