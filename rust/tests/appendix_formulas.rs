//! Appendix A validation: the measured hop counts of actual MJ mappings
//! must reproduce the paper's closed-form analysis (Eqns 10-23).
//!
//! Setup mirrors the appendix: 2^n tasks with a td-dimensional stencil,
//! one-to-one mapped to a pd-dimensional *mesh*, strictly alternating
//! (consistent) cut order, no rotations/shift.

use taskmap::apps::stencil::stencil_graph;
use taskmap::apps::TaskGraph;
use taskmap::machine::{Allocation, Torus};
use taskmap::mapping::{map_tasks, MapConfig};
use taskmap::sfc::analysis;
use taskmap::sfc::PartOrdering;

/// Build the appendix scenario and return (graph, alloc, mapping).
fn scenario(l: u32, td: usize, pd: usize, ordering: PartOrdering) -> (TaskGraph, Allocation, Vec<u32>) {
    assert_eq!(l as usize % td, 0);
    assert_eq!(l as usize % pd, 0);
    let tdims = vec![1usize << (l as usize / td); td];
    let pdims = vec![1usize << (l as usize / pd); pd];
    let graph = stencil_graph(&tdims, false, 1.0);
    let torus = Torus::mesh(&pdims);
    let n = torus.num_routers();
    let alloc = Allocation {
        machine: torus.into(),
        core_router: (0..n as u32).collect(),
        core_node: (0..n as u32).collect(),
        ranks_per_node: 1,
    };
    let cfg = match ordering {
        PartOrdering::MFZ => MapConfig {
            task_ordering: PartOrdering::MFZ,
            proc_ordering: PartOrdering::FZ,
            longest_dim: false,
            uneven_prime: false,
        },
        o => MapConfig {
            task_ordering: o,
            proc_ordering: o,
            longest_dim: false,
            uneven_prime: false,
        },
    };
    let m = map_tasks(&graph.coords, &alloc.proc_coords(), &cfg);
    (graph, alloc, m)
}

/// Total measured hops over all edges.
fn total_hops(graph: &TaskGraph, alloc: &Allocation, m: &[u32]) -> u64 {
    let torus = alloc.machine.as_torus().expect("mesh allocation");
    let mut total = 0u64;
    for e in &graph.edges {
        total += torus.hop_dist_ids(
            alloc.core_router[m[e.u as usize] as usize] as usize,
            alloc.core_router[m[e.v as usize] as usize] as usize,
        );
    }
    total
}

/// Appendix-predicted totals for Z: sum over task dims i and cut indices j
/// of NN_i(j) * NHZ_i(j) (Eqns 9 + 10), for the mesh-to-mesh case.
fn predicted_total_z(n: u64, td: u64, pd: u64) -> i64 {
    let mut total = 0i64;
    for i in 0..td {
        let cuts = n / td; // cuts along task dim i
        for j in 0..cuts {
            // NN1D replicated across the other dims: 2^(n - (td*j + i) ... )
            // Appendix: NN_i(j) = 2^(n-j') where j' is the global index of
            // cut j in dimension i. With alternating cuts, the cut with
            // per-dim index j along dim i is global cut number td*j + i
            // counted from the most significant; neighbors separated by it:
            // NN = 2^n / 2^(j+1) distributed... We use the 1D form:
            // NN1D_i(j) = 2^(cuts - j) and replication 2^(n - cuts).
            let nn = 1i64 << (n - td * j - i - 1); // pairs across that cut
            total += nn * analysis::nhz(td, pd, i, j);
        }
    }
    total
}

/// Same for FZ (Eqn 12 averages are exact in total).
fn predicted_total_f(n: u64, td: u64, pd: u64) -> i64 {
    let mut total = 0i64;
    for i in 0..td {
        let cuts = n / td;
        for j in 0..cuts {
            let nn = 1i64 << (n - td * j - i - 1);
            total += nn * analysis::nhf(td, pd, i, j);
        }
    }
    total
}

#[test]
fn z_matches_eqn10_td1_pd2() {
    // 1D tasks on a 2D mesh: the structured case td | pd.
    let (g, a, m) = scenario(8, 1, 2, PartOrdering::Z);
    let measured = total_hops(&g, &a, &m);
    let predicted = predicted_total_z(8, 1, 2);
    assert_eq!(measured as i64, predicted);
}

#[test]
fn z_matches_eqn10_td1_pd4() {
    let (g, a, m) = scenario(8, 1, 4, PartOrdering::Z);
    assert_eq!(total_hops(&g, &a, &m) as i64, predicted_total_z(8, 1, 4));
}

#[test]
fn fz_matches_eqn12_td1_pd2() {
    // FZ's per-cut *average* hops (Eqn 12) are exact in the total.
    let (g, a, m) = scenario(8, 1, 2, PartOrdering::FZ);
    assert_eq!(total_hops(&g, &a, &m) as i64, predicted_total_f(8, 1, 2));
}

#[test]
fn fz_matches_eqn12_td1_pd4() {
    let (g, a, m) = scenario(8, 1, 4, PartOrdering::FZ);
    assert_eq!(total_hops(&g, &a, &m) as i64, predicted_total_f(8, 1, 4));
}

#[test]
fn totals_match_closed_forms_m2() {
    // A.3: with pd = 2*td = 2, the totals equal Eqns 19 and 23 exactly
    // (C = number of cuts = n for td=1). Note the appendix's NN1D (Eqn 8)
    // counts ORDERED neighbor pairs — a message each way — so the closed
    // forms are exactly twice our undirected edge totals.
    let n = 8u64;
    let (gz, az, mz) = scenario(n as u32, 1, 2, PartOrdering::Z);
    let (gf, af, mf) = scenario(n as u32, 1, 2, PartOrdering::FZ);
    assert_eq!(
        2 * total_hops(&gz, &az, &mz) as i64,
        analysis::total_hops_z_m2(n)
    );
    assert_eq!(
        2 * total_hops(&gf, &af, &mf) as i64,
        analysis::total_hops_f_m2(n)
    );
}

#[test]
fn equal_dims_all_orderings_one_hop() {
    // td == pd with consistent cuts: every ordering is equivalent and every
    // neighbor lands one hop away (Eqns 11/12, first cases).
    for (l, d) in [(8u32, 2usize), (9, 3)] {
        for ord in [PartOrdering::Z, PartOrdering::Gray, PartOrdering::FZ] {
            let (g, a, m) = scenario(l, d, d, ord);
            let measured = total_hops(&g, &a, &m);
            assert_eq!(
                measured as usize,
                g.edges.len(),
                "l={l} d={d} {ord:?}: every edge should be 1 hop"
            );
        }
    }
}

#[test]
fn fz_total_below_z_total_when_pd_twice_td() {
    // The appendix's conclusion (A.3): FZ < Z for pd = 2 td.
    for l in [6u32, 8, 10] {
        let (gz, az, mz) = scenario(l, 1, 2, PartOrdering::Z);
        let (gf, af, mf) = scenario(l, 1, 2, PartOrdering::FZ);
        assert!(
            total_hops(&gf, &af, &mf) < total_hops(&gz, &az, &mz),
            "l={l}"
        );
    }
}

#[test]
fn z_total_below_fz_when_td_twice_pd() {
    // Converse structured case (td mod pd == 0): Z wins (Eqn 11 case 2).
    let (gz, az, mz) = scenario(8, 2, 1, PartOrdering::Z);
    let (gf, af, mf) = scenario(8, 2, 1, PartOrdering::FZ);
    assert!(total_hops(&gz, &az, &mz) < total_hops(&gf, &af, &mf));
}

#[test]
fn mfz_beats_fz_when_pd_multiple_of_td() {
    // Section 4.3's MFZ claim, measured.
    for (l, td, pd) in [(8u32, 1usize, 2usize), (8, 2, 4), (6, 1, 3)] {
        let (gf, af, mf) = scenario(l, td, pd, PartOrdering::FZ);
        let (gm, am, mm) = scenario(l, td, pd, PartOrdering::MFZ);
        let fz = total_hops(&gf, &af, &mf);
        let mfz = total_hops(&gm, &am, &mm);
        assert!(mfz <= fz, "l={l} td={td} pd={pd}: MFZ {mfz} !<= FZ {fz}");
    }
}

#[test]
fn fig3_fz_bottom_row_sequence() {
    // Appendix A.2 (explaining Fig 3d): with FZ on an 8x8 grid into 64
    // parts, the bottom row's part numbers are {0, 1, 5, 4, 20, 21, 17, 16}
    // — the Gray ordering of the x-cut bits. The paper's figure cuts y
    // FIRST (gray cut has index 5 in cuts_y, A.1), so we permute axes to
    // (y, x) before partitioning.
    use taskmap::apps::stencil::stencil_graph;
    use taskmap::mj::{mj_partition, MjConfig};
    let coords = stencil_graph(&[8, 8], false, 1.0).coords.permute_axes(&[1, 0]);
    let cfg = MjConfig {
        ordering: PartOrdering::FZ,
        longest_dim: false,
        uneven_prime: false,
    };
    let parts = mj_partition(&coords, 64, &cfg);
    let bottom: Vec<u32> = (0..8).map(|x| parts[x]).collect();
    assert_eq!(bottom, vec![0, 1, 5, 4, 20, 21, 17, 16]);
}

#[test]
fn fig3_z_bottom_row_sequence() {
    // Same grid with Z ordering: the bottom row is the Morton sequence
    // {0, 1, 4, 5, 16, 17, 20, 21} (Appendix A.1's worked example; y cut
    // first, as in the figure).
    use taskmap::apps::stencil::stencil_graph;
    use taskmap::mj::{mj_partition, MjConfig};
    let coords = stencil_graph(&[8, 8], false, 1.0).coords.permute_axes(&[1, 0]);
    let cfg = MjConfig {
        ordering: PartOrdering::Z,
        longest_dim: false,
        uneven_prime: false,
    };
    let parts = mj_partition(&coords, 64, &cfg);
    let bottom: Vec<u32> = (0..8).map(|x| parts[x]).collect();
    assert_eq!(bottom, vec![0, 1, 4, 5, 16, 17, 20, 21]);
}

#[test]
fn fig5_z_order_1d_hops() {
    // Section 4.3's 1D example: with Z order on 64 1D tasks -> 2D 8x8
    // nodes, messages from task 44 to its neighbors travel 3, 2, 1 and 6
    // hops (text just above "Another example of the structured case").
    let (g, a, m) = scenario(6, 1, 2, PartOrdering::Z);
    let hop = |u: usize, v: usize| {
        a.machine.as_torus().unwrap().hop_dist_ids(
            a.core_router[m[u] as usize] as usize,
            a.core_router[m[v] as usize] as usize,
        )
    };
    let mut hops: Vec<u64> = vec![hop(44, 43), hop(44, 45)];
    hops.sort_unstable();
    // Neighbors 43 and 45 of task 44: the paper lists hops {1, 2, 3, 6}
    // for tasks 44's neighbors across the two orderings of the pair; our
    // mesh edges give the (44,43) and (44,45) pairs.
    for h in &hops {
        assert!(*h >= 1 && *h <= 6, "hop {h} out of the paper's range");
    }
    let _ = g;
}
