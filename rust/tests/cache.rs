//! Determinism suite for the service result cache and request batching.
//!
//! The whole point of caching/batching a mapping service whose parallel
//! paths are bit-identical to sequential execution: a cached, coalesced,
//! or batched reply must be **exactly** the reply a cold, solo run would
//! have produced — at every worker count, for every op family (flat map,
//! hierarchical, hierarchical + coarsening, non-torus topology). These
//! tests pin that, plus the counter bookkeeping (`hits`/`misses`/
//! `inserts`/`bypass`, `flushes + coalesced == jobs`), the per-request
//! `"cache":false` opt-out, and strict validation of the `"cache"` field.

use std::sync::{Arc, Barrier};
use std::time::Duration;

use taskmap::coordinator::service::{error_kind, Client, ErrorKind, Service, ServiceConfig};
use taskmap::testutil::json::Json;

fn svc(workers: usize, cache_capacity: usize, batch_window_ms: u64) -> Service {
    Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers,
            cache_capacity,
            batch_window: Duration::from_millis(batch_window_ms),
            ..ServiceConfig::default()
        },
    )
    .unwrap()
}

/// 8 tasks on a 4x2 grid, optionally shifted so each `variant` is a
/// distinct task set over the same allocation.
fn grid_tcoords(variant: usize) -> String {
    let rows: Vec<String> = (0..8)
        .map(|i| {
            let t = (i + variant) % 8;
            format!("[{}.0,{}.0]", t / 2, t % 2)
        })
        .collect();
    rows.join(",")
}

/// A ring over the 8 tasks with variant-scaled weights.
fn ring_edges(variant: usize) -> String {
    let rows: Vec<String> = (0..8)
        .map(|i| {
            let w = (variant + 1) as f64 * ((i % 3) as f64 + 1.0);
            format!("[{},{},{w}]", i, (i + 1) % 8)
        })
        .collect();
    rows.join(",")
}

/// 2x2 torus, 2 ranks per node.
const TORUS_PCOORDS: &str = "[0,0],[0,0],[0,1],[0,1],[1,0],[1,0],[1,1],[1,1]";

fn req_flat() -> Json {
    let t: Vec<String> = (0..8).map(|i| format!("[{i}.0]")).collect();
    let p: Vec<String> = (0..8).map(|i| format!("[{}.0]", 7 - i)).collect();
    Json::parse(&format!(
        r#"{{"op":"map","tcoords":[{}],"pcoords":[{}]}}"#,
        t.join(","),
        p.join(",")
    ))
    .unwrap()
}

/// Hierarchical map over the torus allocation; `extra` splices additional
/// top-level fields (e.g. `,"cache":false`).
fn req_hier_with(variant: usize, extra: &str) -> Json {
    Json::parse(&format!(
        concat!(
            r#"{{"op":"map","tcoords":[{}],"pcoords":[{}],"edges":[{}],"#,
            r#""hier":{{"ranks_per_node":2,"strategy":"minvol","rotations":4}}{}}}"#
        ),
        grid_tcoords(variant),
        TORUS_PCOORDS,
        ring_edges(variant),
        extra
    ))
    .unwrap()
}

fn req_hier() -> Json {
    req_hier_with(0, "")
}

fn req_hier_coarsen() -> Json {
    req_hier_with(0, r#","coarsen":{"target_tasks":4}"#)
}

/// The same workload on a 2-level radix-2 fat-tree (4 leaves).
fn req_hier_fattree() -> Json {
    Json::parse(&format!(
        concat!(
            r#"{{"op":"map","tcoords":[{}],"pcoords":[[0],[0],[1],[1],[2],[2],[3],[3]],"#,
            r#""edges":[{}],"hier":{{"ranks_per_node":2,"strategy":"minvol","rotations":4}},"#,
            r#""topology":{{"fattree":{{"levels":2,"radix":2}}}}}}"#
        ),
        grid_tcoords(0),
        ring_edges(0)
    ))
    .unwrap()
}

#[test]
fn cached_replies_bit_identical_to_cold_across_worker_counts() {
    let reqs = [req_flat(), req_hier(), req_hier_coarsen(), req_hier_fattree()];
    for &workers in &[1usize, 2, 8] {
        let off = svc(workers, 0, 0);
        let on = svc(workers, 256, 0);
        let mut c_off = Client::connect(off.addr).unwrap();
        let mut c_on = Client::connect(on.addr).unwrap();
        for req in &reqs {
            let cold = c_off.request(req).unwrap();
            assert_eq!(cold.get("ok"), Some(&Json::Bool(true)), "{cold:?}");
            let miss = c_on.request(req).unwrap();
            let hit = c_on.request(req).unwrap();
            assert_eq!(miss, cold, "workers={workers}: miss path must equal cache-off");
            assert_eq!(hit, cold, "workers={workers}: cached reply must be identical");
        }
        // Cache-off stats carry no cache section; cache-on counters
        // reconcile exactly: each request missed once, hit once.
        assert!(off.stats().get("cache").is_none());
        let s = on.stats();
        let cache = s.get("cache").expect("stats carry a cache section");
        let n = reqs.len() as f64;
        let field = |k: &str| cache.get(k).and_then(|v| v.as_f64()).unwrap();
        assert_eq!(field("misses"), n, "{s:?}");
        assert_eq!(field("hits"), n, "{s:?}");
        assert_eq!(field("inserts"), n, "{s:?}");
        assert_eq!(field("entries"), n, "{s:?}");
        assert_eq!(field("evictions"), 0.0, "{s:?}");
        assert_eq!(field("bypass"), 0.0, "{s:?}");
        on.stop();
        off.stop();
    }
}

#[test]
fn cache_opt_out_bypasses_and_validation_stays_strict() {
    let on = svc(2, 256, 0);
    let mut c = Client::connect(on.addr).unwrap();
    // Warm the entry, then opt out: the reply is still identical (pure
    // function) but comes from a fresh computation — bypass advances,
    // hits do not.
    let warm = c.request(&req_hier()).unwrap();
    assert_eq!(warm.get("ok"), Some(&Json::Bool(true)), "{warm:?}");
    let fresh = c.request(&req_hier_with(0, r#","cache":false"#)).unwrap();
    assert_eq!(fresh, warm, "opt-out recomputes the identical reply");
    let s = on.stats();
    let cache = s.get("cache").unwrap();
    assert_eq!(cache.get("bypass").and_then(|v| v.as_f64()), Some(1.0), "{s:?}");
    assert_eq!(cache.get("hits").and_then(|v| v.as_f64()), Some(0.0), "{s:?}");
    // A malformed "cache" value is a structured validation error — even
    // though the entry is warm, validation runs first.
    let bad = c.request(&req_hier_with(0, r#","cache":"yes""#)).unwrap();
    assert_eq!(error_kind(&bad), Some(ErrorKind::InvalidRequest), "{bad:?}");
    // "cache" is a map-only field: eval rejects it.
    let eval = Json::parse(concat!(
        r#"{"op":"eval","map":[0,1,2,3],"edges":[[0,1,2.5]],"#,
        r#""pcoords":[[0,0],[0,0],[1,0],[1,0]],"ranks_per_node":2,"cache":false}"#
    ))
    .unwrap();
    let resp = c.request(&eval).unwrap();
    assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
    on.stop();
}

#[test]
fn batched_replies_bit_identical_to_unbatched_across_worker_counts() {
    // Three compatible requests: same allocation + hier config (one batch
    // group), different task graphs.
    let variants: Vec<Json> = (0..3).map(|v| req_hier_with(v, "")).collect();
    for &workers in &[1usize, 2, 8] {
        let solo = svc(workers, 0, 0); // no cache, no batching
        let batched = svc(workers, 0, 25); // no cache, 25 ms batch window
        let mut c = Client::connect(solo.addr).unwrap();
        let want: Vec<Json> = variants
            .iter()
            .map(|r| {
                let resp = c.request(r).unwrap();
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
                resp
            })
            .collect();
        let barrier = Arc::new(Barrier::new(variants.len()));
        let handles: Vec<_> = variants
            .iter()
            .cloned()
            .map(|req| {
                let barrier = Arc::clone(&barrier);
                let addr = batched.addr;
                std::thread::spawn(move || {
                    barrier.wait();
                    Client::connect(addr).unwrap().request(&req).unwrap()
                })
            })
            .collect();
        let got: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g, w, "workers={workers}: batched reply must equal solo");
        }
        // The flush accounting always reconciles; how much actually
        // coalesced depends on timing, which the invariant absorbs.
        let s = batched.stats();
        let b = s.get("batch").expect("stats carry a batch section");
        let field = |k: &str| b.get(k).and_then(|v| v.as_f64()).unwrap();
        assert_eq!(field("jobs"), variants.len() as f64, "{s:?}");
        assert_eq!(field("flushes") + field("coalesced"), field("jobs"), "{s:?}");
        assert!(solo.stats().get("batch").is_none());
        batched.stop();
        solo.stop();
    }
}
