//! Cross-module integration tests: workloads x machines x strategies x
//! metrics, exercising the same paths as the paper experiments (at small
//! scale).

use taskmap::apps::homme::{Homme, HommeCoords};
use taskmap::apps::minighost::MiniGhost;
use taskmap::apps::stencil::stencil_graph;
use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use taskmap::machine::{cray_xk7, Allocation, SparseAllocator, Torus};
use taskmap::mapping::pipeline::{sfc_plus_z2, z2_map, Z2Config};
use taskmap::mapping::rotations::NativeBackend;
use taskmap::mapping::{map_tasks, MapConfig};
use taskmap::metrics::{eval_full, eval_hops};
use taskmap::sfc::PartOrdering;
use taskmap::simulate::{comm_time, CommModel};

fn titan_small() -> SparseAllocator {
    SparseAllocator {
        machine: cray_xk7(&[8, 8, 8]),
        nodes_per_router: 2,
        ranks_per_node: 16,
        occupancy: 0.35,
    }
}

#[test]
fn minighost_z2_beats_default_on_sparse_allocation() {
    // The paper's headline MiniGhost result, in miniature: on a sparse
    // allocation, the geometric mapping must beat the default task order
    // both in metrics and in simulated communication time.
    let mg = MiniGhost::weak_scaling([8, 8, 8]);
    let graph = mg.graph();
    let alloc = titan_small().allocate(512 / 16, 7);
    let default = mg.default_order();
    let mut cfg = Z2Config::z2_1();
    cfg.max_rotations = 8;
    let z2 = z2_map(&graph, &graph.coords, &alloc, &cfg, &NativeBackend);
    let model = CommModel {
        rounds: 20.0,
        ..Default::default()
    };
    let t_default = comm_time(&graph, &default, &alloc, &model);
    let t_z2 = comm_time(&graph, &z2, &alloc, &model);
    let m_default = eval_hops(&graph, &default, &alloc);
    let m_z2 = eval_hops(&graph, &z2, &alloc);
    assert!(
        m_z2.avg_hops < m_default.avg_hops,
        "hops: Z2 {} !< default {}",
        m_z2.avg_hops,
        m_default.avg_hops
    );
    assert!(
        t_z2.total < t_default.total,
        "time: Z2 {} !< default {}",
        t_z2.total,
        t_default.total
    );
}

#[test]
fn minighost_group_between_default_and_z2() {
    // Paper Fig 13: Group improves on Default; Z2 improves on Group.
    let mg = MiniGhost::weak_scaling([16, 8, 8]);
    let graph = mg.graph();
    let alloc = titan_small().allocate(1024 / 16, 3);
    let model = CommModel {
        rounds: 20.0,
        ..Default::default()
    };
    let t = |m: &[u32]| comm_time(&graph, m, &alloc, &model).total;
    let t_default = t(&mg.default_order());
    let t_group = t(&mg.group_order());
    let mut cfg = Z2Config::z2_1();
    cfg.max_rotations = 8;
    let t_z2 = t(&z2_map(&graph, &graph.coords, &alloc, &cfg, &NativeBackend));
    assert!(t_group < t_default, "group {t_group} !< default {t_default}");
    assert!(t_z2 < t_group, "z2 {t_z2} !< group {t_group}");
}

#[test]
fn homme_bgq_z2_reduces_data_at_scale() {
    // Section 5.2's mechanism: SFC over-uses D/E links on BG/Q; Z2
    // distributes data across dimensions, lowering Data(M).
    let homme = Homme::new(16); // 1536 elements
    let graph = homme.graph();
    let alloc = Allocation::bgq([4, 4, 4, 2, 2], 4, "ABCDET").unwrap(); // 512 ranks
    let sfc = homme.sfc_partition(alloc.num_ranks());
    let mut cfg = Z2Config::z2_1().plus_e();
    cfg.max_rotations = 6;
    let face = homme.coords(HommeCoords::Face2D);
    let z2 = z2_map(&graph, &face, &alloc, &cfg, &NativeBackend);
    let m_sfc = eval_full(&graph, &sfc, &alloc);
    let m_z2 = eval_full(&graph, &z2, &alloc);
    // At this toy scale the paper reports no decisive Data(M) win (Table 2
    // shows none at 8K either); the *mechanism* must hold though: SFC
    // concentrates traffic on few dimensions while Z2 balances it.
    let imbalance = |m: &taskmap::metrics::Metrics| {
        let lm = m.link.as_ref().unwrap();
        let avgs: Vec<f64> = (0..5)
            .map(|d| 0.5 * (lm.per_dim[d][0].avg_data + lm.per_dim[d][1].avg_data))
            .collect();
        let mean = avgs.iter().sum::<f64>() / 5.0;
        avgs.iter().cloned().fold(0.0, f64::max) / mean
    };
    let (i_sfc, i_z2) = (imbalance(&m_sfc), imbalance(&m_z2));
    assert!(
        i_z2 < i_sfc,
        "link-utilization imbalance: Z2 {i_z2:.2} !< SFC {i_sfc:.2}"
    );
    // And Data(M) must at least stay in the same ballpark (< 1.5x).
    let d_sfc = m_sfc.link.unwrap().max_data;
    let d_z2 = m_z2.link.unwrap().max_data;
    assert!(d_z2 < 1.5 * d_sfc, "Data(M): Z2 {d_z2} way above SFC {d_sfc}");
}

#[test]
fn homme_sfc_plus_z2_preserves_parts() {
    let homme = Homme::new(8);
    let graph = homme.graph();
    let alloc = Allocation::bgq([2, 2, 2, 2, 2], 4, "ABCDET").unwrap(); // 128 ranks
    let parts = homme.sfc_partition(alloc.num_ranks());
    let mut cfg = Z2Config::z2_1();
    cfg.max_rotations = 4;
    let m = sfc_plus_z2(
        &graph,
        &homme.coords(HommeCoords::Cube),
        &parts,
        alloc.num_ranks(),
        &alloc,
        &cfg,
        &NativeBackend,
    );
    // Same part -> same rank; mapping is a bijection over ranks.
    let mut rank_of_part = vec![None; alloc.num_ranks()];
    for t in 0..graph.num_tasks {
        let p = parts[t] as usize;
        match rank_of_part[p] {
            None => rank_of_part[p] = Some(m[t]),
            Some(r) => assert_eq!(r, m[t]),
        }
    }
    let mut ranks: Vec<u32> = rank_of_part.into_iter().map(|r| r.unwrap()).collect();
    ranks.sort_unstable();
    ranks.dedup();
    assert_eq!(ranks.len(), alloc.num_ranks());
}

#[test]
fn shifted_mapping_improves_seam_straddling_allocation() {
    // Build an allocation hugging the torus seam; with shifting the mapper
    // must see it as contiguous and produce a mapping at least as good as
    // without shifting.
    let machine = Torus::torus(&[16]);
    // Routers 14,15,0,1 around the seam; 4 ranks per router-node.
    let routers = [14u32, 15, 0, 1];
    let alloc = Allocation {
        machine: machine.into(),
        core_router: routers.iter().flat_map(|&r| [r; 4]).collect(),
        core_node: (0..4u32).flat_map(|n| [n; 4]).collect(),
        ranks_per_node: 4,
    };
    let graph = stencil_graph(&[16], false, 1.0);
    let run = |shift: bool| {
        let cfg = Z2Config {
            shift,
            max_rotations: 1,
            ..Z2Config::z2_1()
        };
        let m = z2_map(&graph, &graph.coords, &alloc, &cfg, &NativeBackend);
        eval_hops(&graph, &m, &alloc).weighted_hops
    };
    assert!(run(true) <= run(false));
}

#[test]
fn table1_style_mapping_all_orderings_bijective() {
    // 2D tasks onto 3D nodes, 512 each, every ordering.
    let tg = stencil_graph(&[32, 16], false, 1.0);
    let nodes = Torus::torus(&[8, 8, 8]);
    let alloc = Allocation {
        machine: nodes.into(),
        core_router: (0..512u32).collect(),
        core_node: (0..512u32).collect(),
        ranks_per_node: 1,
    };
    for ord in [
        PartOrdering::Z,
        PartOrdering::Gray,
        PartOrdering::FZ,
        PartOrdering::Hilbert,
    ] {
        let cfg = MapConfig {
            task_ordering: ord,
            proc_ordering: ord,
            longest_dim: false,
            uneven_prime: false,
        };
        let m = map_tasks(&tg.coords, &alloc.proc_coords(), &cfg);
        let mut s = m.clone();
        s.sort_unstable();
        assert_eq!(s, (0..512u32).collect::<Vec<_>>(), "{ord:?}");
        // Sanity: AverageHops bounded by the network diameter.
        let hops = eval_hops(&tg, &m, &alloc);
        assert!(hops.avg_hops <= 12.0, "{ord:?}: {}", hops.avg_hops);
    }
}

#[test]
fn uneven_prime_avoids_splitting_nodes_early() {
    // 48 ranks = 3 nodes x 16: prime bisection (p=3 at the top) must not
    // split any node across the first cut; every node's ranks then map to
    // tasks forming one contiguous cluster.
    let machine = Torus::torus(&[8, 1, 1]);
    let alloc = Allocation {
        machine: machine.into(),
        core_router: (0..3u32).flat_map(|r| [r; 16]).collect(),
        core_node: (0..3u32).flat_map(|n| [n; 16]).collect(),
        ranks_per_node: 16,
    };
    let graph = stencil_graph(&[48], false, 1.0);
    let run = |uneven: bool| {
        let cfg = Z2Config {
            uneven_prime: uneven,
            shift: false,
            max_rotations: 1,
            ..Z2Config::z2_1()
        };
        let m = z2_map(&graph, &graph.coords, &alloc, &cfg, &NativeBackend);
        // Count inter-node task edges: fewer = nodes own contiguous blocks.
        graph
            .edges
            .iter()
            .filter(|e| {
                alloc.core_node[m[e.u as usize] as usize]
                    != alloc.core_node[m[e.v as usize] as usize]
            })
            .count()
    };
    let uneven = run(true);
    let even = run(false);
    assert!(uneven <= even, "uneven {uneven} !<= even {even}");
    assert_eq!(uneven, 2, "3 contiguous blocks of 16 have exactly 2 cut edges");
}

#[test]
fn hier_bijective_and_beats_default_on_minighost() {
    // The two-level contract end-to-end: bijection, node-respecting, and
    // (with MinVolume refinement) better inter-node metrics than the
    // application's default order on a sparse allocation.
    let mg = MiniGhost::weak_scaling([8, 8, 8]);
    let graph = mg.graph();
    let alloc = titan_small().allocate(512 / 16, 7);
    let cfg = HierConfig {
        intra: IntraNodeStrategy::MinVolume { passes: 4 },
        max_rotations: 8,
        ..HierConfig::default()
    };
    let m = map_hierarchical(&graph, &graph.coords, &alloc, &cfg, &NativeBackend);
    let mut s = m.task_to_rank.clone();
    s.sort_unstable();
    assert_eq!(s, (0..512u32).collect::<Vec<_>>());
    for t in 0..512 {
        assert_eq!(
            alloc.core_node[m.task_to_rank[t] as usize],
            m.task_to_node[t]
        );
    }
    let m_hier = eval_hops(&graph, &m.task_to_rank, &alloc);
    let m_default = eval_hops(&graph, &mg.default_order(), &alloc);
    assert!(
        m_hier.weighted_hops < m_default.weighted_hops,
        "hier {} !< default {}",
        m_hier.weighted_hops,
        m_default.weighted_hops
    );
}

#[test]
fn numa_depth3_end_to_end_on_minighost() {
    // Depth-3 contract end-to-end on the XK7 Interlagos node model:
    // bijection, node- and socket-respecting, the cross-socket refinement
    // never loses to the raw geometric split, and the NumaAware value is
    // exactly its per-level recomposition.
    use taskmap::hier::socket::split_sockets;
    use taskmap::machine::NumaTopology;
    use taskmap::objective::{eval_numa, eval_numa_placement};
    use taskmap::par::Parallelism;
    let mg = MiniGhost::weak_scaling([8, 8, 8]);
    let graph = mg.graph();
    let alloc = titan_small().allocate(512 / 16, 7);
    let topo = NumaTopology::xk7();
    let cfg = HierConfig {
        intra: IntraNodeStrategy::MinVolume { passes: 4 },
        max_rotations: 8,
        spec: taskmap::mapping::MapSpec {
            numa: Some(topo),
            ..Default::default()
        },
        ..HierConfig::default()
    };
    let m = map_hierarchical(&graph, &graph.coords, &alloc, &cfg, &NativeBackend);
    let mut s = m.task_to_rank.clone();
    s.sort_unstable();
    assert_eq!(s, (0..512u32).collect::<Vec<_>>());
    let socks = m.task_to_socket.as_ref().expect("depth 3 reports sockets");
    let rank_socks = topo.socket_of_ranks(&alloc);
    for t in 0..512 {
        let rank = m.task_to_rank[t] as usize;
        assert_eq!(alloc.core_node[rank], m.task_to_node[t], "task {t}");
        assert_eq!(rank_socks[rank], socks[t], "task {t}");
    }
    // Each 16-rank node splits 8/8 across its two dies.
    let mut per_socket = vec![0usize; alloc.num_nodes() * 2];
    for t in 0..512 {
        per_socket[m.task_to_node[t] as usize * 2 + socks[t] as usize] += 1;
    }
    assert!(per_socket.iter().all(|&c| c == 8), "{per_socket:?}");
    // The refined sockets must not be worse than the raw geometric split
    // (refinement applies only strictly-improving swaps).
    let routers = alloc.node_routers();
    let raw = split_sockets(
        &graph.coords,
        &m.task_to_node,
        &alloc,
        &topo,
        Parallelism::auto(),
    );
    let cross =
        |sk: &[u32]| {
            eval_numa_placement(&graph, &m.task_to_node, sk, &routers, &alloc.machine, &topo)
                .socket_weight
        };
    assert!(
        cross(socks) <= cross(&raw) + 1e-9,
        "refined {} > raw split {}",
        cross(socks),
        cross(&raw)
    );
    // The NumaAware value recomposes exactly from its breakdown.
    let nm = eval_numa(&graph, &m.task_to_rank, &alloc, &topo);
    let recomposed = topo.hop_cost * nm.network_weighted_hops
        + topo.socket_cost * nm.socket_weight
        + topo.core_cost * nm.core_weight;
    assert_eq!(nm.value, recomposed);
}

#[test]
fn hier_homme_bijective_on_titan_preset() {
    // One rank per element (the experiment's HOMME configuration).
    let homme = Homme::new(8); // 384 elements
    let graph = homme.graph();
    let alloc = titan_small().allocate(384 / 16, 3);
    let cfg = HierConfig {
        intra: IntraNodeStrategy::SfcOrder,
        max_rotations: 6,
        ..HierConfig::default()
    };
    let tcoords = homme.coords(HommeCoords::Cube);
    let m = map_hierarchical(&graph, &tcoords, &alloc, &cfg, &NativeBackend);
    let mut s = m.task_to_rank.clone();
    s.sort_unstable();
    assert_eq!(s, (0..384u32).collect::<Vec<_>>());
    // Every node holds exactly ranks_per_node tasks.
    let mut per_node = vec![0usize; alloc.num_nodes()];
    for &n in &m.task_to_node {
        per_node[n as usize] += 1;
    }
    assert!(per_node.iter().all(|&c| c == 16), "{per_node:?}");
}

#[test]
fn metrics_consistent_between_eval_paths() {
    let mg = MiniGhost::weak_scaling([8, 8, 4]);
    let graph = mg.graph();
    let alloc = titan_small().allocate(16, 9);
    let m = mg.group_order();
    let cheap = eval_hops(&graph, &m, &alloc);
    let full = eval_full(&graph, &m, &alloc);
    assert_eq!(cheap.total_hops, full.total_hops);
    assert_eq!(cheap.total_messages, full.total_messages);
    assert!(full.link.is_some());
}

#[test]
fn weak_scaling_z2_hops_stay_flat() {
    // Fig 14's claim: AverageHops under Z2 stays nearly constant as the
    // job grows, while Default's grows.
    let allocator = titan_small();
    let mut z2_hops = Vec::new();
    let mut default_hops = Vec::new();
    for (procs, dims) in [(256usize, [4usize, 8, 8]), (2048, [16, 16, 8])] {
        let mg = MiniGhost::weak_scaling(dims);
        let graph = mg.graph();
        let alloc = allocator.allocate(procs / 16, 21);
        let mut cfg = Z2Config::z2_1();
        cfg.max_rotations = 6;
        let z2 = z2_map(&graph, &graph.coords, &alloc, &cfg, &NativeBackend);
        z2_hops.push(eval_hops(&graph, &z2, &alloc).avg_hops);
        default_hops.push(eval_hops(&graph, &mg.default_order(), &alloc).avg_hops);
    }
    // Absolute hop growth under weak scaling: Z2's increase must stay
    // below Default's, and Z2 must stay below Default at every scale.
    let z2_growth = z2_hops[1] - z2_hops[0];
    let default_growth = default_hops[1] - default_hops[0];
    assert!(
        z2_growth < default_growth,
        "z2 growth {z2_growth} !< default growth {default_growth} ({z2_hops:?} vs {default_hops:?})"
    );
    assert!(z2_hops[0] < default_hops[0] && z2_hops[1] < default_hops[1]);
}
