//! Observability invariants: tracing must never change a mapping.
//!
//! The obs recorder is compiled into every pipeline layer, so these tests
//! pin the contract that makes it safe to ship enabled-by-flag: with
//! tracing captured per-thread, enabled globally, or streamed to a JSONL
//! sink, the hierarchical mapping is **bit-identical** to the untraced run
//! at every thread budget (the CI matrix re-runs this binary under
//! `TASKMAP_THREADS=1/2/8`), and a captured span tree replays with an
//! identical structure for a fixed input and budget.
//!
//! Tests that flip process-global recorder state (the enabled flag, the
//! JSONL sink, `TASKMAP_TRACE`) serialize on one mutex so the harness's
//! parallel test threads cannot observe each other's half-configured
//! state; capture-based tests are per-thread and need no lock.

use std::sync::{Mutex, MutexGuard};

use taskmap::apps::stencil::stencil_graph;
use taskmap::apps::TaskGraph;
use taskmap::hier::{map_hierarchical, HierConfig, HierMapping, IntraNodeStrategy};
use taskmap::machine::{Allocation, NumaTopology, SparseAllocator, Torus};
use taskmap::mapping::rotations::NativeBackend;
use taskmap::obs;

/// Serializes the tests that mutate global recorder state.
static GLOBAL_RECORDER: Mutex<()> = Mutex::new(());

fn global_lock() -> MutexGuard<'static, ()> {
    match GLOBAL_RECORDER.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn toy_alloc() -> Allocation {
    SparseAllocator {
        machine: Torus::torus(&[6, 6, 6]),
        nodes_per_router: 2,
        ranks_per_node: 8,
        occupancy: 0.3,
    }
    .allocate(16, 5) // 128 ranks
}

fn toy_graph() -> TaskGraph {
    stencil_graph(&[8, 4, 4], false, 1.0) // 128 tasks
}

/// The full depth-3 pipeline (sweep + refine + socket + place) under an
/// explicit thread budget — the widest instrumented surface in one call.
fn run_map(graph: &TaskGraph, alloc: &Allocation, threads: usize) -> HierMapping {
    let cfg = HierConfig {
        intra: IntraNodeStrategy::MinVolume { passes: 2 },
        max_rotations: 4,
        spec: taskmap::mapping::MapSpec {
            threads,
            numa: Some(NumaTopology::new(2, 4, 0.5, 0.0, 1.0)),
            ..Default::default()
        },
        ..HierConfig::default()
    };
    map_hierarchical(graph, &graph.coords, alloc, &cfg, &NativeBackend)
}

/// `0` = auto (sized by `TASKMAP_THREADS` under the CI matrix).
const BUDGETS: [usize; 4] = [1, 2, 8, 0];

#[test]
fn captured_tracing_leaves_mapping_bit_identical() {
    let alloc = toy_alloc();
    let g = toy_graph();
    for threads in BUDGETS {
        let baseline = run_map(&g, &alloc, threads);
        let (traced, events) = obs::capture(|| run_map(&g, &alloc, threads));
        assert_eq!(traced.task_to_rank, baseline.task_to_rank, "threads={threads}");
        assert_eq!(traced.task_to_node, baseline.task_to_node, "threads={threads}");
        assert_eq!(traced.task_to_socket, baseline.task_to_socket, "threads={threads}");
        assert_eq!(traced.node_score, baseline.node_score, "threads={threads}");
        assert!(!events.is_empty(), "capture saw no events at threads={threads}");
    }
}

#[test]
fn global_recorder_leaves_mapping_bit_identical() {
    let alloc = toy_alloc();
    let g = toy_graph();
    // Baselines under the lock too: a concurrently-enabled recorder must
    // not change them either, but the assertion is cleanest off/on.
    let guard = global_lock();
    obs::set_enabled(false);
    let baselines: Vec<HierMapping> =
        BUDGETS.iter().map(|&t| run_map(&g, &alloc, t)).collect();
    obs::set_enabled(true);
    for (&threads, baseline) in BUDGETS.iter().zip(&baselines) {
        let traced = run_map(&g, &alloc, threads);
        assert_eq!(traced.task_to_rank, baseline.task_to_rank, "threads={threads}");
        assert_eq!(traced.node_score, baseline.node_score, "threads={threads}");
    }
    obs::set_enabled(false);
    drop(guard);
}

#[test]
fn jsonl_sink_leaves_mapping_bit_identical_and_validates() {
    let alloc = toy_alloc();
    let g = toy_graph();
    let path = std::env::temp_dir().join(format!("taskmap_obs_sink_{}.jsonl", std::process::id()));
    let path_str = path.to_str().expect("temp path is utf-8").to_string();

    let guard = global_lock();
    obs::set_enabled(false);
    let baseline = run_map(&g, &alloc, 2);
    // The TASKMAP_TRACE flavor: refresh_env installs the sink and enables
    // the recorder exactly as Service::start would.
    std::env::set_var("TASKMAP_TRACE", &path_str);
    obs::refresh_env();
    std::env::remove_var("TASKMAP_TRACE");
    assert!(obs::enabled(), "refresh_env enables the recorder");
    let traced = run_map(&g, &alloc, 2);
    obs::trace::clear_sink();
    obs::set_enabled(false);
    drop(guard);

    assert_eq!(traced.task_to_rank, baseline.task_to_rank);
    assert_eq!(traced.node_score, baseline.node_score);
    // Every line the sink wrote validates against the documented schema.
    let text = std::fs::read_to_string(&path).expect("sink file written");
    let lines = obs::trace::validate_jsonl(&text)
        .unwrap_or_else(|e| panic!("sink JSONL failed validation: {e}"));
    assert!(lines >= 1, "sink wrote no events");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn span_tree_replays_identically_for_fixed_input() {
    let alloc = toy_alloc();
    let g = toy_graph();
    let (a, events_a) = obs::capture(|| run_map(&g, &alloc, 2));
    let (b, events_b) = obs::capture(|| run_map(&g, &alloc, 2));
    assert_eq!(a.task_to_rank, b.task_to_rank);
    // The structural digest (nesting, kinds, names, field names — no
    // timing) must be byte-identical across runs.
    let da = obs::trace::structural_digest(&events_a);
    let db = obs::trace::structural_digest(&events_b);
    assert_eq!(da, db);
    // And the digest covers every instrumented phase.
    for name in [
        "hier.sweep",
        "hier.refine",
        "hier.socket",
        "hier.place",
        "sweep.candidate",
        "refine.pass",
        "deadline.check",
    ] {
        assert!(da.contains(name), "digest missing {name}:\n{da}");
    }
}

/// CI hook: `TASKMAP_TRACE_CHECK=<path>` points this test at a trace file
/// produced by a real service run (the workflow smoke-runs
/// `mapping_service` under `TASKMAP_TRACE` and then validates the
/// artifact here). Without the env var it validates a self-generated
/// trace, so the check never silently passes on nothing.
#[test]
fn trace_file_validates_against_documented_schema() {
    if let Ok(path) = std::env::var("TASKMAP_TRACE_CHECK") {
        if !path.is_empty() {
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("TASKMAP_TRACE_CHECK={path}: {e}"));
            let lines = obs::trace::validate_jsonl(&text)
                .unwrap_or_else(|e| panic!("{path}: {e}"));
            assert!(lines >= 1, "{path}: trace file is empty");
            return;
        }
    }
    // Self-generated flavor: capture a pipeline run and validate the
    // JSONL rendering of every event.
    let alloc = toy_alloc();
    let g = toy_graph();
    let (_, events) = obs::capture(|| run_map(&g, &alloc, 1));
    let mut text = String::new();
    for e in &events {
        if let Some(json) = obs::trace::event_json(e) {
            text.push_str(&json.to_string());
            text.push('\n');
        }
    }
    let lines = obs::trace::validate_jsonl(&text).unwrap_or_else(|e| panic!("{e}"));
    assert!(lines >= 1);
}
