//! PJRT runtime tests: the AOT HLO artifacts must load, execute, and agree
//! with the native evaluator bit-for-bit (same f32 accumulation contract).
//!
//! Requires `make artifacts` (skipped with a note if absent — CI runs it).

use taskmap::mapping::rotations::{score_mappings, NativeBackend, WhopsBackend};
use taskmap::metrics::native::batched_weighted_hops_native;
use taskmap::runtime::{PjrtBackend, PjrtRuntime};
use taskmap::testutil::Rng;

fn runtime() -> Option<PjrtRuntime> {
    match PjrtRuntime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts unavailable ({e}); run `make artifacts`");
            None
        }
    }
}

fn random_case(
    rng: &mut Rng,
    r: usize,
    e: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let dims: Vec<f32> = (0..d).map(|_| rng.range(1, 17) as f32).collect();
    let coord = |rng: &mut Rng, dims: &[f32], k: usize| (rng.below(dims[k % d] as usize)) as f32;
    let src: Vec<f32> = (0..r * e * d).map(|k| coord(rng, &dims, k)).collect();
    let dst: Vec<f32> = (0..r * e * d).map(|k| coord(rng, &dims, k)).collect();
    let w: Vec<f32> = (0..e).map(|_| rng.f64_range(0.0, 4.0) as f32).collect();
    let wrap: Vec<f32> = (0..d).map(|_| if rng.bool() { 1.0 } else { 0.0 }).collect();
    (src, dst, w, dims, wrap)
}

#[test]
fn pjrt_matches_native_exact_artifact_shape() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let (r, e, d) = (2, 1024, 6); // the smoke artifact's exact shape
    let (src, dst, w, dims, wrap) = random_case(&mut rng, r, e, d);
    let got = rt.eval(&src, &dst, &w, &dims, &wrap, r, e, d).unwrap();
    let want = batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, r, e, d);
    for (g, want) in got.iter().zip(&want) {
        assert!(
            (g - want).abs() <= 1e-2 + want.abs() * 1e-5,
            "{g} vs {want}"
        );
    }
}

#[test]
fn pjrt_pads_edges_and_dims() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    // Odd sizes force both edge-chunk padding and dim padding.
    let (r, e, d) = (3, 1500, 3);
    let (src, dst, w, dims, wrap) = random_case(&mut rng, r, e, d);
    let got = rt.eval(&src, &dst, &w, &dims, &wrap, r, e, d).unwrap();
    let want = batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, r, e, d);
    for (g, want) in got.iter().zip(&want) {
        assert!(
            (g - want).abs() <= 1e-2 + want.abs() * 1e-5,
            "{g} vs {want}"
        );
    }
}

#[test]
fn pjrt_chunks_candidates() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    // More candidates than any artifact's R: forces candidate chunking.
    let (r, e, d) = (41, 256, 4);
    let (src, dst, w, dims, wrap) = random_case(&mut rng, r, e, d);
    let got = rt.eval(&src, &dst, &w, &dims, &wrap, r, e, d).unwrap();
    let want = batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, r, e, d);
    assert_eq!(got.len(), r);
    for (g, want) in got.iter().zip(&want) {
        assert!(
            (g - want).abs() <= 1e-2 + want.abs() * 1e-5,
            "{g} vs {want}"
        );
    }
}

#[test]
fn pjrt_backend_scores_match_native_backend() {
    let Some(backend) = PjrtBackend::try_default() else {
        eprintln!("SKIP: artifacts unavailable");
        return;
    };
    use taskmap::apps::stencil::stencil_graph;
    use taskmap::machine::{Allocation, Torus};
    let g = stencil_graph(&[8, 8], false, 2.0);
    let torus = Torus::torus(&[8, 8]);
    let alloc = Allocation {
        torus,
        core_router: (0..64u32).collect(),
        core_node: (0..64u32).collect(),
        ranks_per_node: 1,
    };
    let mut rng = Rng::new(4);
    let mappings: Vec<Vec<u32>> = (0..5)
        .map(|_| {
            let mut m: Vec<u32> = (0..64).collect();
            rng.shuffle(&mut m);
            m
        })
        .collect();
    let pjrt = score_mappings(&g, &mappings, &alloc, &backend, 1024);
    let native = score_mappings(&g, &mappings, &alloc, &NativeBackend, 1024);
    for (a, b) in pjrt.iter().zip(&native) {
        assert!((a - b).abs() <= 1e-2 + b.abs() * 1e-5, "{a} vs {b}");
    }
    assert_eq!(*backend.fallbacks.lock().unwrap(), 0, "PJRT silently fell back");
}

#[test]
fn pjrt_rejects_oversized_dims_gracefully() {
    let Some(backend) = PjrtBackend::try_default() else {
        eprintln!("SKIP: artifacts unavailable");
        return;
    };
    // d=8 exceeds every artifact (D=6): the backend must fall back to
    // native, not panic, and still return correct values.
    let mut rng = Rng::new(5);
    let (r, e, d) = (2, 64, 8);
    let (src, dst, w, dims, wrap) = random_case(&mut rng, r, e, d);
    let got = backend.eval_batch(&src, &dst, &w, &dims, &wrap, r, e, d);
    let want = batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, r, e, d);
    assert_eq!(got, want);
    assert_eq!(*backend.fallbacks.lock().unwrap(), 1);
}
