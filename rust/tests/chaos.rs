//! Chaos suite: the mapping service under deterministic fault injection.
//!
//! Every test installs a seeded [`FaultPlan`] (possibly empty — the install
//! lock also serializes chaos tests against each other) and proves one of
//! the service's robustness invariants:
//!
//! - every accepted request is answered or the connection is closed — never
//!   silently hung;
//! - an injected handler panic becomes a structured `internal` error and
//!   the worker pool stays healthy;
//! - overload sheds with a structured `overloaded` reply carrying the
//!   `retry_after_ms` hint, and the retry client rides it out;
//! - shutdown drains in-flight work, and force-closes stragglers within
//!   the drain deadline;
//! - fault decisions are bit-reproducible: the same seed replays the same
//!   outcome sequence at every pool size (CI runs this suite at
//!   `TASKMAP_THREADS=1/2/8` with a pinned `TASKMAP_FAULT_SEED`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use taskmap::coordinator::service::{
    error_kind, error_retry_after_ms, request_with_retry, Client, ErrorKind, RetryPolicy,
    Service, ServiceConfig,
};
use taskmap::testutil::faults::{install, would_fire, FaultAction, FaultPlan};
use taskmap::testutil::json::Json;

/// The chaos seed: pinned in CI via `TASKMAP_FAULT_SEED` so every lane
/// replays the identical fault schedule.
fn fault_seed() -> u64 {
    std::env::var("TASKMAP_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xCAFE_BABE)
}

/// What one raw ping attempt observed.
#[derive(Debug, PartialEq, Eq)]
enum Outcome {
    Pong,
    Error(ErrorKind),
    /// The server closed (or reset) the connection without a parseable
    /// reply — e.g. a shed refusal raced a TCP reset.
    Disconnected,
}

/// One ping on a fresh connection with a bounded read: a hung server fails
/// the test instead of hanging it.
fn ping_once(addr: std::net::SocketAddr, read_timeout: Duration) -> Outcome {
    let mut stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => return Outcome::Disconnected,
    };
    stream.set_read_timeout(Some(read_timeout)).unwrap();
    if stream.write_all(b"{\"op\":\"ping\"}\n").is_err() {
        return Outcome::Disconnected;
    }
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) | Err(_) => Outcome::Disconnected,
        Ok(_) => match Json::parse(line.trim()) {
            Ok(resp) if resp.get("ok") == Some(&Json::Bool(true)) => Outcome::Pong,
            Ok(resp) => match error_kind(&resp) {
                Some(kind) => Outcome::Error(kind),
                None => Outcome::Disconnected,
            },
            Err(_) => Outcome::Disconnected,
        },
    }
}

fn stats(addr: std::net::SocketAddr) -> Json {
    let mut client = Client::connect(addr).unwrap();
    client
        .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
        .unwrap()
}

#[test]
fn every_request_is_answered_under_injected_slowness() {
    let seed = fault_seed();
    let guard = install(FaultPlan::new(seed).site(
        "service.handler",
        FaultAction::SleepMs(10),
        0.5,
    ));
    let svc = Service::start("127.0.0.1:0").unwrap();
    let addr = svc.addr;
    const CLIENTS: usize = 6;
    const REQS: usize = 3;
    let answered = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let answered = Arc::clone(&answered);
            std::thread::spawn(move || {
                for _ in 0..REQS {
                    assert_eq!(ping_once(addr, Duration::from_secs(10)), Outcome::Pong);
                    answered.fetch_add(1, Ordering::Relaxed);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(answered.load(Ordering::Relaxed), CLIENTS * REQS);
    // Determinism: hit-to-thread assignment races, but the number of fires
    // among the first N hits is a pure function of (seed, site) — assert
    // the exact count the seed predicts.
    let total = (CLIENTS * REQS) as u64;
    assert_eq!(guard.plan().hits("service.handler"), total);
    let predicted = (0..total)
        .filter(|&h| would_fire(seed, "service.handler", h, 0.5))
        .count() as u64;
    assert_eq!(guard.plan().fires("service.handler"), predicted);
    svc.stop();
}

#[test]
fn injected_panics_become_internal_errors_and_spare_the_pool() {
    let guard = install(FaultPlan::new(fault_seed()).site_limited(
        "service.handler.panic",
        FaultAction::Panic,
        1.0,
        3,
    ));
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 2,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    // First three requests hit the armed panic; the pool answers each one
    // with a structured internal error and keeps serving.
    for i in 0..6 {
        let outcome = ping_once(svc.addr, Duration::from_secs(5));
        if i < 3 {
            assert_eq!(outcome, Outcome::Error(ErrorKind::Internal), "request {i}");
        } else {
            assert_eq!(outcome, Outcome::Pong, "request {i}");
        }
    }
    assert_eq!(guard.plan().fires("service.handler.panic"), 3);
    // The panics are counted and their messages are in the ring buffer.
    let s = stats(svc.addr);
    assert_eq!(s.get("panics").and_then(|v| v.as_f64()), Some(3.0));
    assert_eq!(
        s.get("errors")
            .and_then(|e| e.get("internal"))
            .and_then(|v| v.as_f64()),
        Some(3.0)
    );
    let recent = s.get("recent").unwrap().as_arr().unwrap();
    assert!(
        recent
            .iter()
            .any(|e| e.as_str().unwrap().contains("service.handler.panic")),
        "{recent:?}"
    );
    svc.stop();
}

#[test]
fn overload_sheds_with_structured_reply_and_retry_hint() {
    // Every request sleeps 250 ms on a single worker with a queue of one:
    // most of a simultaneous burst of 8 must be shed, immediately, with
    // the backpressure hint.
    let _guard = install(FaultPlan::new(fault_seed()).site(
        "service.handler",
        FaultAction::SleepMs(250),
        1.0,
    ));
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 1,
            queue_capacity: 1,
            retry_after_ms: 25,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = svc.addr;
    const BURST: usize = 8;
    let barrier = Arc::new(Barrier::new(BURST));
    let handles: Vec<_> = (0..BURST)
        .map(|_| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let mut stream = TcpStream::connect(addr).unwrap();
                stream
                    .set_read_timeout(Some(Duration::from_secs(10)))
                    .unwrap();
                stream.write_all(b"{\"op\":\"ping\"}\n").unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                match reader.read_line(&mut line) {
                    // A shed refusal can race the TCP reset of the dropped
                    // socket; a clean read gives the structured reply.
                    Ok(0) | Err(_) => None,
                    Ok(_) => Some(Json::parse(line.trim()).unwrap()),
                }
            })
        })
        .collect();
    let mut pongs = 0usize;
    let mut shed_seen = 0usize;
    let mut dropped = 0usize;
    for h in handles {
        match h.join().unwrap() {
            Some(resp) if resp.get("ok") == Some(&Json::Bool(true)) => pongs += 1,
            Some(resp) => {
                assert_eq!(error_kind(&resp), Some(ErrorKind::Overloaded), "{resp:?}");
                assert_eq!(error_retry_after_ms(&resp), Some(25), "{resp:?}");
                assert_eq!(
                    resp.get("error").and_then(|e| e.get("retryable")),
                    Some(&Json::Bool(true))
                );
                shed_seen += 1;
            }
            None => dropped += 1,
        }
    }
    assert_eq!(pongs + shed_seen + dropped, BURST);
    assert!(pongs >= 1, "at least the first request must be served");
    assert!(
        shed_seen + dropped >= 1,
        "a burst of {BURST} through a 1-worker/1-slot pool must shed"
    );
    // Server-side accounting closes the loop: accepted = served + shed,
    // so even replies lost to a TCP reset were answered before the close.
    let s = stats(addr);
    let shed = s.get("shed").and_then(|v| v.as_f64()).unwrap() as usize;
    assert_eq!(shed, shed_seen + dropped, "{s:?}");
    assert!(
        s.get("accepted").and_then(|v| v.as_f64()).unwrap() as usize >= BURST,
        "{s:?}"
    );
    svc.stop();
}

#[test]
fn malformed_traffic_is_contained_and_pool_stays_healthy() {
    // No faults — but hold the install lock so no other plan leaks in.
    let _guard = install(FaultPlan::new(fault_seed()));
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 2,
            max_payload: 512,
            read_timeout: Duration::from_millis(150),
            frame_timeout: Duration::from_millis(250),
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = svc.addr;

    // Garbage bytes: a structured bad-json error, connection stays usable.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    stream.write_all(b"\x01\x02 garbage \x7f\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
    // Same connection still serves valid requests afterwards.
    stream.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"), "{line:?}");
    drop((stream, reader));

    // Mid-request disconnect: the worker just moves on.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(b"{\"op\":\"map\",\"tco").unwrap();
    drop(stream);

    // Oversized payload: structured refusal, then the server closes.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let big = format!("{{\"op\":\"ping\",\"pad\":\"{}\"}}\n", "x".repeat(2048));
    stream.write_all(big.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "server must close");

    // Trickle stall: a frame that never completes is timed out and
    // answered, releasing the worker.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    stream.write_all(b"{\"op\":\"pi").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
    assert!(
        resp.get("error")
            .and_then(|e| e.get("message"))
            .and_then(|m| m.as_str())
            .unwrap()
            .contains("truncated"),
        "{resp:?}"
    );

    // After all of that, the pool is fully healthy.
    assert_eq!(ping_once(addr, Duration::from_secs(5)), Outcome::Pong);
    let s = stats(addr);
    assert!(
        s.get("errors")
            .and_then(|e| e.get("invalid_request"))
            .and_then(|v| v.as_f64())
            .unwrap()
            >= 3.0,
        "{s:?}"
    );
    assert_eq!(s.get("panics").and_then(|v| v.as_f64()), Some(0.0));
    svc.stop();
}

#[test]
fn graceful_drain_answers_in_flight_requests() {
    let _guard = install(FaultPlan::new(fault_seed()).site(
        "service.handler",
        FaultAction::SleepMs(150),
        1.0,
    ));
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 1,
            drain_timeout: Duration::from_secs(2),
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = svc.addr;
    let client = std::thread::spawn(move || ping_once(addr, Duration::from_secs(5)));
    // Let the request reach the worker, then shut down while it sleeps.
    std::thread::sleep(Duration::from_millis(60));
    svc.stop();
    // Drain waited for the in-flight request: the client still got its
    // answer.
    assert_eq!(client.join().unwrap(), Outcome::Pong);
    // And the listener is gone.
    assert!(TcpStream::connect(addr).is_err());
}

#[test]
fn drain_force_closes_stragglers_within_the_deadline() {
    let _guard = install(FaultPlan::new(fault_seed()).site(
        "service.handler",
        FaultAction::SleepMs(1500),
        1.0,
    ));
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 1,
            drain_timeout: Duration::from_millis(100),
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = svc.addr;
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        stream.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        let mut buf = Vec::new();
        // The handler sleeps 1.5 s but drain force-closes our socket at
        // ~100 ms: read returns (EOF or reset) long before the handler
        // finishes. Time that observation.
        let start = Instant::now();
        let _ = stream.read_to_end(&mut buf);
        (start.elapsed(), buf)
    });
    std::thread::sleep(Duration::from_millis(80));
    let stop_started = Instant::now();
    svc.stop();
    let stop_elapsed = stop_started.elapsed();
    let (client_elapsed, buf) = client.join().unwrap();
    // The socket was closed within the drain deadline (plus margin), not
    // after the 1.5 s handler sleep.
    assert!(
        client_elapsed < Duration::from_millis(1000),
        "client observed close after {client_elapsed:?}"
    );
    // No pong made it out before the force-close.
    assert!(!String::from_utf8_lossy(&buf).contains("pong"), "{buf:?}");
    // stop() itself may join the sleeping worker (bounded by the injected
    // 1.5 s sleep), but never hangs.
    assert!(stop_elapsed < Duration::from_secs(5), "{stop_elapsed:?}");
}

#[test]
fn retry_client_rides_out_transient_overload() {
    // Only the first request sleeps (fire budget 1): it pins the single
    // worker for 300 ms while a second idle connection fills the one queue
    // slot — the retry client gets shed, backs off per retry_after_ms, and
    // succeeds once the pool frees up.
    let guard = install(FaultPlan::new(fault_seed()).site_limited(
        "service.handler",
        FaultAction::SleepMs(300),
        1.0,
        1,
    ));
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 1,
            queue_capacity: 1,
            retry_after_ms: 20,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = svc.addr;
    let slow = std::thread::spawn(move || ping_once(addr, Duration::from_secs(10)));
    std::thread::sleep(Duration::from_millis(30));
    // Fill the queue slot with a connection that never speaks, then closes.
    let filler = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(250));
        drop(stream);
    });
    std::thread::sleep(Duration::from_millis(30));
    let policy = RetryPolicy {
        max_attempts: 10,
        base_delay_ms: 15,
        max_delay_ms: 200,
        seed: fault_seed(),
    };
    let req = Json::obj(vec![("op", Json::Str("ping".into()))]);
    let resp = request_with_retry(addr, &req, &policy).expect("retry client succeeds");
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    assert_eq!(slow.join().unwrap(), Outcome::Pong);
    filler.join().unwrap();
    assert_eq!(guard.plan().fires("service.handler"), 1);
    svc.stop();
}

/// A small hierarchical map request — the cached/batched op family.
fn hier_map_req() -> Json {
    Json::parse(concat!(
        r#"{"op":"map","tcoords":[[0,0],[0,1],[1,0],[1,1]],"#,
        r#""pcoords":[[0,0],[0,0],[1,0],[1,0]],"#,
        r#""edges":[[0,1,2.5],[2,3,1.0]],"hier":{"ranks_per_node":2}}"#
    ))
    .unwrap()
}

#[test]
fn cache_leader_panic_fails_over_cleanly_and_never_poisons_the_cache() {
    // Arm exactly one panic at the cache-miss leader site: the first
    // request to win the single-flight slot dies mid-compute while
    // identical requests are coalesced behind it.
    let guard = install(FaultPlan::new(fault_seed()).site_limited(
        "service.cache.leader.panic",
        FaultAction::Panic,
        1.0,
        1,
    ));
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 4,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let addr = svc.addr;
    const CLIENTS: usize = 4;
    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let mut client = Client::connect(addr).unwrap();
                client.request(&hier_map_req()).unwrap()
            })
        })
        .collect();
    // Invariant: every follower is answered — an internal error (leader
    // died while they waited) or a fresh successful computation — never a
    // hang and never a poisoned reply.
    let (mut oks, mut internals) = (0usize, 0usize);
    for h in handles {
        let resp = h.join().unwrap();
        if resp.get("ok") == Some(&Json::Bool(true)) {
            assert!(resp.get("map").is_some(), "{resp:?}");
            oks += 1;
        } else {
            assert_eq!(error_kind(&resp), Some(ErrorKind::Internal), "{resp:?}");
            internals += 1;
        }
    }
    assert_eq!(oks + internals, CLIENTS);
    assert!(
        internals >= 1,
        "the panicking leader itself must surface an internal error"
    );
    assert_eq!(guard.plan().fires("service.cache.leader.panic"), 1);
    // The failed flight was un-poisoned: a fresh identical request
    // computes (or hits a successfully recomputed entry), and a repeat is
    // served from the cache bit-identically.
    let mut client = Client::connect(addr).unwrap();
    let first = client.request(&hier_map_req()).unwrap();
    assert_eq!(first.get("ok"), Some(&Json::Bool(true)), "{first:?}");
    let second = client.request(&hier_map_req()).unwrap();
    assert_eq!(first, second, "cached reply must match the computed one");
    let s = stats(addr);
    let cache = s.get("cache").expect("stats carry a cache section");
    assert_eq!(
        cache.get("leader_failures").and_then(|v| v.as_f64()),
        Some(1.0),
        "{s:?}"
    );
    assert!(
        cache.get("hits").and_then(|v| v.as_f64()).unwrap() >= 1.0,
        "{s:?}"
    );
    svc.stop();
}

#[test]
fn slow_cache_lookups_still_answer_every_request() {
    // A stalled lookup path (e.g. shard-lock contention) must delay, not
    // drop or corrupt: every request is answered with the identical reply
    // and the hit/miss accounting stays exact.
    let guard = install(FaultPlan::new(fault_seed()).site(
        "service.cache.lookup",
        FaultAction::SleepMs(20),
        1.0,
    ));
    let svc = Service::start_with(
        "127.0.0.1:0",
        ServiceConfig {
            workers: 2,
            ..ServiceConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(svc.addr).unwrap();
    const REQS: usize = 4;
    let mut replies = Vec::new();
    for _ in 0..REQS {
        let resp = client.request(&hier_map_req()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        replies.push(resp);
    }
    assert!(
        replies.windows(2).all(|w| w[0] == w[1]),
        "cached replies must be identical to the cold one"
    );
    assert_eq!(guard.plan().hits("service.cache.lookup"), REQS as u64);
    let s = stats(svc.addr);
    let cache = s.get("cache").expect("stats carry a cache section");
    assert_eq!(cache.get("misses").and_then(|v| v.as_f64()), Some(1.0), "{s:?}");
    assert_eq!(
        cache.get("hits").and_then(|v| v.as_f64()),
        Some((REQS - 1) as f64),
        "{s:?}"
    );
    svc.stop();
}

#[test]
fn fault_decisions_reproduce_bit_for_bit_across_pool_sizes() {
    let seed = fault_seed();
    const REQS: u64 = 16;
    let site = "service.handler.panic";
    let predicted: Vec<bool> = (0..REQS).map(|h| would_fire(seed, site, h, 0.35)).collect();
    assert!(
        predicted.iter().any(|&b| b) && !predicted.iter().all(|&b| b),
        "seed {seed} should mix outcomes; got {predicted:?}"
    );
    let mut runs: Vec<Vec<bool>> = Vec::new();
    for &workers in &[1usize, 2, 8] {
        let guard = install(FaultPlan::new(seed).site(site, FaultAction::Panic, 0.35));
        let svc = Service::start_with(
            "127.0.0.1:0",
            ServiceConfig {
                workers,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        // Sequential requests: hit k of the site is exactly request k, so
        // each individual outcome is predictable, not just the counts.
        let outcomes: Vec<bool> = (0..REQS)
            .map(|i| {
                match ping_once(svc.addr, Duration::from_secs(5)) {
                    Outcome::Error(ErrorKind::Internal) => true,
                    Outcome::Pong => false,
                    other => panic!("request {i}: unexpected outcome {other:?}"),
                }
            })
            .collect();
        assert_eq!(guard.plan().hits(site), REQS, "workers={workers}");
        assert_eq!(
            outcomes, predicted,
            "workers={workers}: outcome sequence must match the seed's schedule"
        );
        runs.push(outcomes);
        svc.stop();
        drop(guard);
    }
    // All pool sizes replayed the identical schedule.
    assert!(runs.windows(2).all(|w| w[0] == w[1]));
}
