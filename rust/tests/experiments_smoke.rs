//! Smoke tests: every registered experiment runs end-to-end at small scale
//! and produces well-formed tables with the paper's qualitative shape.

use taskmap::coordinator::{experiments, Ctx};

fn ctx() -> Ctx {
    Ctx::new(false, 42, true) // small scale, native backend (fast, no I/O)
}

fn parse(cell: &str) -> f64 {
    cell.parse().unwrap_or(f64::NAN)
}

#[test]
fn all_experiments_run_and_render() {
    let ctx = ctx();
    for id in experiments::ALL {
        let tables = experiments::run(id, &ctx).expect("registered");
        assert!(!tables.is_empty(), "{id}: no tables");
        for t in &tables {
            assert!(!t.rows.is_empty(), "{id}: empty table {}", t.title);
            let md = t.markdown();
            assert!(md.contains('|'), "{id}: markdown broken");
            for row in &t.rows {
                assert_eq!(row.len(), t.headers.len(), "{id}: ragged row");
            }
        }
    }
}

#[test]
fn unknown_experiment_is_none() {
    assert!(experiments::run("fig99", &ctx()).is_none());
}

#[test]
fn table1_fz_geomean_beats_z() {
    // The paper's ordering conclusion: FZ's geomean AverageHops is below
    // Z's in every connectivity group.
    let tables = experiments::run("table1", &ctx()).unwrap();
    let t = &tables[0];
    let geo = t.rows.last().unwrap();
    assert_eq!(geo[0], "GEOMEAN");
    // Columns: 3 key cols then per group [H, Z, FZ, MFZ].
    for group in 0..3 {
        let base = 3 + group * 4;
        let z = parse(&geo[base + 1]);
        let fz = parse(&geo[base + 2]);
        assert!(
            fz < z,
            "group {group}: FZ geomean {fz} !< Z geomean {z}"
        );
    }
}

#[test]
fn table1_mfz_improves_on_fz_geomean() {
    let tables = experiments::run("table1", &ctx()).unwrap();
    let geo = tables[0].rows.last().unwrap().clone();
    for group in 0..3 {
        let base = 3 + group * 4;
        let fz = parse(&geo[base + 2]);
        let mfz = parse(&geo[base + 3]);
        // MFZ geomean is over the subset of rows where it applies, so
        // compare loosely: it must not be dramatically worse.
        assert!(
            mfz < fz * 1.15,
            "group {group}: MFZ {mfz} much worse than FZ {fz}"
        );
    }
}

#[test]
fn fig13_z2_beats_default_at_scale() {
    let tables = experiments::run("fig13", &ctx()).unwrap();
    let t = &tables[0];
    // Headers: procs, allocs, Default, Group, Z2_1, Z2_2, Z2_3.
    let last = t.rows.last().unwrap();
    let default = parse(&last[2]);
    let z2_1 = parse(&last[4]);
    assert!(
        z2_1 < default,
        "Z2_1 {z2_1} !< Default {default} at the largest scale"
    );
}

#[test]
fn fig10_normalizes_sfc_to_one() {
    let tables = experiments::run("fig10", &ctx()).unwrap();
    for row in &tables[0].rows {
        let sfc = parse(&row[2]);
        assert!((sfc - 1.0).abs() < 1e-9, "SFC column must be 1.00");
    }
}

#[test]
fn fig14_reports_both_metrics() {
    let tables = experiments::run("fig14", &ctx()).unwrap();
    assert_eq!(tables.len(), 2);
    assert!(tables[0].title.contains("AverageHops"));
    assert!(tables[1].title.contains("Latency"));
}

#[test]
fn objective_reports_both_metrics_side_by_side() {
    // Rows come in (strategy x 3 objectives) groups; the whops-objective
    // row of each group is the ratio denominator (1.00 / 1.00), and every
    // ratio is finite and positive.
    let tables = experiments::run("objective", &ctx()).unwrap();
    assert_eq!(tables.len(), 1);
    let t = &tables[0];
    assert_eq!(t.rows.len() % 3, 0, "rows must group by objective triples");
    for chunk in t.rows.chunks(3) {
        assert_eq!(chunk[0][3], "whops");
        assert_eq!(chunk[1][3], "maxload");
        assert_eq!(chunk[2][3], "blend");
        assert_eq!(chunk[0][6], "1.00");
        assert_eq!(chunk[0][7], "1.00");
        for row in chunk {
            for col in [6, 7] {
                let v = parse(&row[col]);
                assert!(v.is_finite() && v > 0.0, "bad ratio {v} in {row:?}");
            }
        }
        // Flat rows: both objectives pick from the same candidate set, so
        // the maxload argmin's bottleneck can never exceed the whops
        // pick's. Hier rows: refinement paths differ, so only sanity-bound.
        let lat_ratio = parse(&chunk[1][7]);
        let bound = if chunk[0][2] == "flat" { 1.005 } else { 2.0 };
        assert!(
            lat_ratio <= bound,
            "maxload objective's bottleneck ratio {lat_ratio} > {bound} ({:?})",
            chunk[1]
        );
    }
}

#[test]
fn numa_compares_depths_on_both_presets() {
    let tables = experiments::run("numa", &ctx()).unwrap();
    assert_eq!(tables.len(), 2);
    assert!(tables[0].title.contains("MiniGhost"));
    assert!(tables[1].title.contains("HOMME"));
    for t in &tables {
        // Rows come in (depth-2 whops, depth-3 whops, depth-3 maxload)
        // triples; the depth-2 row normalizes the ratios to 1.00.
        assert_eq!(t.rows.len() % 3, 0, "{}", t.title);
        for chunk in t.rows.chunks(3) {
            assert_eq!(chunk[0][2], "depth-2");
            assert_eq!(chunk[1][2], "depth-3");
            assert_eq!(chunk[2][2], "depth-3");
            assert_eq!(chunk[0][3], "whops");
            assert_eq!(chunk[1][3], "whops");
            assert_eq!(chunk[2][3], "maxload");
            assert_eq!(chunk[0][8], "1.00");
            assert_eq!(chunk[0][9], "1.00");
            assert_eq!(chunk[0][10], "1.00");
            for row in chunk {
                for col in [4, 5, 6, 7] {
                    let v = parse(&row[col]);
                    assert!(v.is_finite() && v >= 0.0, "bad value {v} in {row:?}");
                }
                for col in [8, 9, 10] {
                    let v = parse(&row[col]);
                    assert!(v.is_finite() && v >= 0.0, "bad ratio {v} in {row:?}");
                }
            }
            // The explicit socket split must not lose badly to socket-blind
            // placement on the NUMA objective (it typically wins outright).
            let value_ratio = parse(&chunk[1][8]);
            assert!(
                value_ratio < 1.15,
                "{}: depth-3 NUMA value ratio {value_ratio} way above depth-2 ({:?})",
                t.title,
                chunk[1]
            );
        }
    }
}

#[test]
fn hier_compares_both_presets_against_flat() {
    let tables = experiments::run("hier", &ctx()).unwrap();
    assert_eq!(tables.len(), 2);
    assert!(tables[0].title.contains("MiniGhost"));
    assert!(tables[1].title.contains("HOMME"));
    for t in &tables {
        // Four strategies per (case, seed); flat rows normalize to 1.00.
        assert_eq!(t.rows.len() % 4, 0, "{}", t.title);
        for chunk in t.rows.chunks(4) {
            assert_eq!(chunk[0][2], "Flat Z2_1");
            assert_eq!(chunk[0][6], "1.00");
            assert_eq!(chunk[3][2], "Hier minvol");
            // Every ratio parses to a finite positive number.
            for row in chunk {
                for col in [6, 7, 8] {
                    let v = parse(&row[col]);
                    assert!(
                        v.is_finite() && v > 0.0,
                        "{}: bad ratio {v} in {row:?}",
                        t.title
                    );
                }
            }
            // The refined hierarchy must not lose badly to the flat mapper
            // on its own objective (typically it wins outright).
            let wh_ratio = parse(&chunk[3][6]);
            assert!(
                wh_ratio < 1.25,
                "{}: hier minvol WH ratio {wh_ratio} way above flat ({:?})",
                t.title,
                chunk[3]
            );
        }
    }
}
