//! Property-based tests (seeded-random harness in `testutil::prop`):
//! invariants of the partitioner, the mapping, the orderings, the machine
//! model, and the transforms under randomized inputs.

use taskmap::apps::stencil::stencil_graph;
use taskmap::machine::{Allocation, BwModel, SparseAllocator, Torus};
use taskmap::mapping::shift::shift_dim;
use taskmap::mapping::{map_tasks, MapConfig, MapSpec};
use taskmap::metrics::native::batched_weighted_hops_native;
use taskmap::metrics::{eval_full, eval_hops};
use taskmap::mj::{mj_partition, MjConfig};
use taskmap::sfc::hilbert::{hilbert_index, hilbert_point};
use taskmap::sfc::PartOrdering;
use taskmap::testutil::prop::{
    approx_eq, check, random_coords, random_part_ordering as random_ordering, THREAD_COUNTS,
};
use taskmap::testutil::Rng;

#[test]
fn prop_mj_partition_sizes_balanced() {
    check("mj sizes balanced", 40, |rng| {
        let n = rng.range(1, 400);
        let np = rng.range(1, n + 1);
        let dim = rng.range(1, 5);
        let coords = random_coords(rng, n, dim, 16);
        let cfg = MjConfig {
            ordering: random_ordering(rng),
            longest_dim: rng.bool(),
            uneven_prime: rng.bool(),
        };
        let parts = mj_partition(&coords, np, &cfg);
        let mut sizes = vec![0usize; np];
        for &p in &parts {
            if (p as usize) >= np {
                return Err(format!("part {p} out of range {np}"));
            }
            sizes[p as usize] += 1;
        }
        let (base, extra) = (n / np, n % np);
        for (p, &s) in sizes.iter().enumerate() {
            let want = base + usize::from(p < extra);
            if s != want {
                return Err(format!("part {p}: {s} != {want} (n={n} np={np})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mapping_is_balanced_assignment() {
    check("mapping balanced", 30, |rng| {
        let pnum = rng.range(2, 64);
        let mult = rng.range(1, 5);
        let tnum = pnum * mult + rng.below(pnum); // tnum >= pnum
        let td = rng.range(1, 4);
        let pd = rng.range(1, 4);
        let t = random_coords(rng, tnum, td, 32);
        let p = random_coords(rng, pnum, pd, 32);
        let cfg = MapConfig {
            task_ordering: random_ordering(rng),
            proc_ordering: random_ordering(rng),
            longest_dim: rng.bool(),
            uneven_prime: rng.bool(),
        };
        let m = map_tasks(&t, &p, &cfg);
        let mut loads = vec![0usize; pnum];
        for &r in &m {
            loads[r as usize] += 1;
        }
        let (min, max) = (
            *loads.iter().min().unwrap(),
            *loads.iter().max().unwrap(),
        );
        if max - min > 1 {
            return Err(format!("unbalanced loads: min {min} max {max}"));
        }
        Ok(())
    });
}

#[test]
fn prop_one_to_one_mapping_bijective() {
    check("bijection", 30, |rng| {
        let n = rng.range(2, 256);
        let td = rng.range(1, 4);
        let pd = rng.range(1, 5);
        let t = random_coords(rng, n, td, 64);
        let p = random_coords(rng, n, pd, 64);
        let cfg = MapConfig {
            task_ordering: random_ordering(rng),
            proc_ordering: random_ordering(rng),
            longest_dim: rng.bool(),
            uneven_prime: rng.bool(),
        };
        let m = map_tasks(&t, &p, &cfg);
        let mut seen = vec![false; n];
        for &r in &m {
            if seen[r as usize] {
                return Err(format!("rank {r} assigned twice"));
            }
            seen[r as usize] = true;
        }
        Ok(())
    });
}

#[test]
fn prop_shift_preserves_cyclic_distances() {
    check("shift isometry", 50, |rng| {
        let size = rng.range(4, 64);
        let n = rng.range(2, 40);
        let mut vals: Vec<f64> = (0..n).map(|_| rng.below(size) as f64).collect();
        let orig = vals.clone();
        shift_dim(&mut vals, size);
        // Torus distance between every pair must be preserved.
        let tdist = |a: f64, b: f64| {
            let d = (a - b).abs() % size as f64;
            d.min(size as f64 - d)
        };
        for i in 0..n {
            for j in 0..n {
                let before = tdist(orig[i], orig[j]);
                let after = tdist(vals[i], vals[j]);
                approx_eq(before, after, 0.0, 1e-9)
                    .map_err(|e| format!("pair ({i},{j}): {e}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hilbert_roundtrip_random_dims() {
    check("hilbert roundtrip", 60, |rng| {
        let d = rng.range(1, 7);
        let bits = rng.range(1, (128 / d).min(8) + 1) as u32;
        let p: Vec<u64> = (0..d).map(|_| rng.below(1 << bits) as u64).collect();
        let idx = hilbert_index(&p, bits);
        let back = hilbert_point(idx, d, bits);
        if back != p {
            return Err(format!("{p:?} -> {idx} -> {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_native_whops_matches_eval_hops() {
    // The f32 kernel-twin and the f64 metrics engine must agree on
    // WeightedHops for one-rank-per-node allocations.
    check("native whops == metrics", 25, |rng| {
        let d = rng.range(1, 4);
        let sizes: Vec<usize> = (0..d).map(|_| rng.range(2, 9)).collect();
        let torus = Torus::torus(&sizes);
        let n = torus.num_routers();
        let alloc = Allocation {
            machine: torus.clone().into(),
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        };
        let tdims: Vec<usize> = sizes.clone();
        let graph = stencil_graph(&tdims, rng.bool(), rng.range(1, 100) as f64);
        let mut mapping: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut mapping);
        let metric = eval_hops(&graph, &mapping, &alloc);
        // Build kernel inputs.
        let e = graph.edges.len();
        let mut src = vec![0f32; e * d];
        let mut dst = vec![0f32; e * d];
        let mut w = vec![0f32; e];
        let mut buf = vec![0usize; d];
        for (k, edge) in graph.edges.iter().enumerate() {
            w[k] = edge.w as f32;
            torus.coords_into(mapping[edge.u as usize] as usize, &mut buf);
            for i in 0..d {
                src[k * d + i] = buf[i] as f32;
            }
            torus.coords_into(mapping[edge.v as usize] as usize, &mut buf);
            for i in 0..d {
                dst[k * d + i] = buf[i] as f32;
            }
        }
        let dims: Vec<f32> = sizes.iter().map(|&s| s as f32).collect();
        let wrap = vec![1f32; d];
        let got = batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, 1, e, d)[0];
        approx_eq(got as f64, metric.weighted_hops, 1e-5, 1e-2)
    });
}

#[test]
fn prop_data_conservation() {
    // Sum of Data over all links == sum over inter-node edges of
    // 2 * w * hops (each byte traverses hops links, both directions).
    check("data conservation", 20, |rng| {
        let sizes: Vec<usize> = (0..3).map(|_| rng.range(2, 6)).collect();
        let alloc = SparseAllocator {
            machine: Torus::new(sizes.clone(), vec![true; 3], BwModel::Gemini),
            nodes_per_router: 2,
            ranks_per_node: 2,
            occupancy: 0.2,
        }
        .allocate(rng.range(4, 12), rng.next_u64());
        let nt = alloc.num_ranks();
        let graph = stencil_graph(&[nt], false, 3.0);
        let mut mapping: Vec<u32> = (0..nt as u32).collect();
        rng.shuffle(&mut mapping);
        let m = eval_full(&graph, &mapping, &alloc);
        let lm = m.link.unwrap();
        // Recompute total link data from per-dim averages * link counts is
        // lossy; instead recompute expected total directly.
        let torus = alloc.machine.as_torus().expect("torus allocation");
        let mut expected = 0f64;
        for e in &graph.edges {
            let (ra, rb) = (mapping[e.u as usize] as usize, mapping[e.v as usize] as usize);
            if alloc.core_node[ra] == alloc.core_node[rb] {
                continue;
            }
            let h = torus.hop_dist_ids(
                alloc.core_router[ra] as usize,
                alloc.core_router[rb] as usize,
            ) as f64;
            expected += 2.0 * e.w * h;
        }
        let total_links = torus.num_directed_links() as f64;
        approx_eq(lm.avg_data * total_links, expected, 1e-9, 1e-6)
    });
}

#[test]
fn prop_rotation_candidates_are_valid_perms() {
    check("rotation perms", 20, |rng| {
        let td = rng.range(1, 5);
        let pd = rng.range(1, 5);
        let cap = rng.range(1, 50);
        for (tp, pp) in taskmap::mapping::rotations::candidate_rotations(td, pd, cap) {
            let mut t = tp.clone();
            t.sort_unstable();
            if t != (0..td).collect::<Vec<_>>() {
                return Err(format!("bad tperm {tp:?}"));
            }
            let mut p = pp.clone();
            p.sort_unstable();
            if p != (0..pd).collect::<Vec<_>>() {
                return Err(format!("bad pperm {pp:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mapping_quality_never_catastrophic() {
    // Geometric mapping of a stencil onto a matching torus must stay within
    // a small constant factor of 1 hop per edge (sanity against regressions
    // that silently scramble the mapping).
    check("quality bound", 10, |rng| {
        let k = 1 << rng.range(2, 4); // 4 or 8
        let g = stencil_graph(&[k, k], false, 1.0);
        let torus = Torus::torus(&[k, k]);
        let n = torus.num_routers();
        let alloc = Allocation {
            torus,
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        };
        let cfg = MapConfig::with_ordering(PartOrdering::FZ);
        let m = map_tasks(&g.coords, &alloc.proc_coords(), &cfg);
        let hops = eval_hops(&g, &m, &alloc);
        if hops.avg_hops > 2.5 {
            return Err(format!("avg hops {} > 2.5 on matched grids", hops.avg_hops));
        }
        Ok(())
    });
}

#[test]
fn prop_mj_partition_parallel_bit_identical() {
    // The fork–join MJ recursion must reproduce the sequential partition
    // exactly — every ordering, every part count, every thread budget. The
    // tiny grain forces real recursion splits on these small inputs.
    use taskmap::par::Parallelism;
    check("mj parallel == sequential", 30, |rng| {
        let n = rng.range(2, 600);
        let np = rng.range(1, n + 1);
        let dim = rng.range(1, 5);
        let coords = random_coords(rng, n, dim, 16);
        let cfg = MjConfig {
            ordering: random_ordering(rng),
            longest_dim: rng.bool(),
            uneven_prime: rng.bool(),
        };
        let seq = taskmap::mj::mj_partition_par(&coords, np, &cfg, Parallelism::sequential());
        for &threads in THREAD_COUNTS.iter() {
            let par = taskmap::mj::mj_partition_par(
                &coords,
                np,
                &cfg,
                Parallelism::threads(threads).with_grain(4),
            );
            if par != seq {
                return Err(format!("diverged at threads={threads} (n={n} np={np})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mj_multisection_parallel_bit_identical() {
    use taskmap::mj::{mj_multisection_par, multisection::MultisectionConfig};
    use taskmap::par::Parallelism;
    check("multisection parallel == sequential", 20, |rng| {
        let dim = rng.range(1, 4);
        let rd = rng.range(1, 4);
        let counts: Vec<usize> = (0..rd).map(|_| rng.range(2, 5)).collect();
        let p: usize = counts.iter().product();
        let n = p * rng.range(1, 6) + rng.below(p);
        let coords = random_coords(rng, n, dim, 32);
        let cfg = MultisectionConfig {
            counts,
            longest_dim: rng.bool(),
        };
        let seq = mj_multisection_par(&coords, &cfg, Parallelism::sequential());
        for &threads in THREAD_COUNTS.iter() {
            let par = mj_multisection_par(
                &coords,
                &cfg,
                Parallelism::threads(threads).with_grain(4),
            );
            if par != seq {
                return Err(format!("diverged at threads={threads} ({cfg:?})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rotation_sweep_parallel_bit_identical() {
    // The fanned-out sweep (memoized proc partitions, per-worker scratch
    // arenas, chunked scoring) must reproduce the sequential sweep exactly:
    // same chosen candidate, bit-equal scores, same mapping.
    use taskmap::mapping::rotations::{rotation_sweep, NativeBackend, SweepConfig};
    check("rotation sweep parallel == sequential", 8, |rng| {
        let tx = rng.range(2, 6);
        let ty = rng.range(2, 6);
        let n = tx * ty;
        let g = stencil_graph(&[tx, ty], rng.bool(), rng.range(1, 5) as f64);
        let alloc = Allocation {
            machine: Torus::torus(&[ty, tx]).into(),
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        };
        let p = alloc.proc_coords();
        let map_cfg = MapConfig {
            task_ordering: random_ordering(rng),
            proc_ordering: random_ordering(rng),
            longest_dim: rng.bool(),
            uneven_prime: rng.bool(),
        };
        // Full 2D×2D candidate product (4 candidates), several scoring
        // chunks per candidate.
        let sweep = |threads: usize| SweepConfig {
            max_candidates: 4,
            chunk_edges: 7,
            spec: MapSpec {
                threads,
                ..MapSpec::default()
            },
        };
        let seq = rotation_sweep(
            &g,
            &g.coords,
            &p,
            &alloc,
            &map_cfg,
            &sweep(1),
            &NativeBackend,
        );
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par = rotation_sweep(
                &g,
                &g.coords,
                &p,
                &alloc,
                &map_cfg,
                &sweep(threads),
                &NativeBackend,
            );
            if par.chosen != seq.chosen {
                return Err(format!("chosen {} != {} at threads={threads}", par.chosen, seq.chosen));
            }
            if par.scores != seq.scores {
                return Err(format!("scores diverged at threads={threads}"));
            }
            if par.task_to_rank != seq.task_to_rank {
                return Err(format!("mapping diverged at threads={threads}"));
            }
        }
        // The memoized proc-side path must also equal mapping materialized
        // permuted coordinates directly (the pre-memoization semantics).
        let (tp, pp) = &seq.candidates[seq.chosen];
        let direct = map_tasks(&g.coords.permute_axes(tp), &p.permute_axes(pp), &map_cfg);
        if seq.task_to_rank != direct {
            return Err("memoized sweep mapping != direct map_tasks".into());
        }
        Ok(())
    });
}

#[test]
fn prop_score_mappings_parallel_bit_identical() {
    use taskmap::mapping::rotations::{score_mappings_par, NativeBackend};
    use taskmap::par::Parallelism;
    check("score_mappings parallel == sequential", 10, |rng| {
        let k = rng.range(3, 7);
        let n = k * k;
        let g = stencil_graph(&[k, k], rng.bool(), rng.f64_range(0.5, 4.0));
        let alloc = Allocation {
            machine: Torus::torus(&[k, k]).into(),
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        };
        let mappings: Vec<Vec<u32>> = (0..rng.range(1, 9))
            .map(|_| {
                let mut m: Vec<u32> = (0..n as u32).collect();
                rng.shuffle(&mut m);
                m
            })
            .collect();
        let chunk = rng.range(1, 64);
        let seq = score_mappings_par(
            &g,
            &mappings,
            &alloc,
            &NativeBackend,
            chunk,
            Parallelism::sequential(),
        );
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par = score_mappings_par(
                &g,
                &mappings,
                &alloc,
                &NativeBackend,
                chunk,
                Parallelism::threads(threads),
            );
            if par != seq {
                return Err(format!("scores diverged at threads={threads}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_eval_full_parallel_bit_identical() {
    // The chunked metrics engine must be bitwise-equal at every thread
    // budget for a fixed chunk size — including multi-chunk merges forced
    // by tiny chunks.
    use taskmap::metrics::eval_full_chunked;
    use taskmap::par::Parallelism;
    check("eval_full parallel == sequential", 15, |rng| {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[rng.range(3, 7), rng.range(3, 7), rng.range(3, 7)]),
            nodes_per_router: 2,
            ranks_per_node: rng.range(1, 5),
            occupancy: rng.f64_range(0.0, 0.4),
        }
        .allocate(rng.range(2, 10), rng.next_u64());
        let nt = alloc.num_ranks();
        let graph = stencil_graph(&[nt], rng.bool(), rng.f64_range(0.5, 5.0));
        let mut mapping: Vec<u32> = (0..nt as u32).collect();
        rng.shuffle(&mut mapping);
        let chunk = rng.range(1, 32);
        let seq = eval_full_chunked(&graph, &mapping, &alloc, Parallelism::sequential(), chunk);
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par =
                eval_full_chunked(&graph, &mapping, &alloc, Parallelism::threads(threads), chunk);
            if par != seq {
                return Err(format!("metrics diverged at threads={threads} chunk={chunk}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hier_mapping_parallel_bit_identical_and_bijective() {
    // The full two-level mapper — node sweep, MinVolume refinement,
    // intra-node placement — must reproduce the sequential result exactly
    // at every thread budget, and produce a bijection when tnum == ranks.
    use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
    use taskmap::mapping::rotations::NativeBackend;
    check("hier parallel == sequential", 8, |rng| {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[5, 5, 5]),
            nodes_per_router: 2,
            ranks_per_node: rng.range(2, 5),
            occupancy: rng.f64_range(0.0, 0.3),
        }
        .allocate(rng.range(3, 9), rng.next_u64());
        let nt = alloc.num_ranks();
        let graph = stencil_graph(&[nt], false, rng.f64_range(0.5, 3.0));
        let intra = match rng.below(3) {
            0 => IntraNodeStrategy::DefaultOrder,
            1 => IntraNodeStrategy::SfcOrder,
            _ => IntraNodeStrategy::MinVolume { passes: 3 },
        };
        let mk = |threads: usize| HierConfig {
            intra,
            max_rotations: 4,
            spec: MapSpec {
                threads,
                ..MapSpec::default()
            },
            ..HierConfig::default()
        };
        let seq = map_hierarchical(&graph, &graph.coords, &alloc, &mk(1), &NativeBackend);
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par = map_hierarchical(&graph, &graph.coords, &alloc, &mk(threads), &NativeBackend);
            if par.task_to_node != seq.task_to_node {
                return Err(format!("node assignment diverged at threads={threads}"));
            }
            if par.task_to_rank != seq.task_to_rank {
                return Err(format!("rank mapping diverged at threads={threads}"));
            }
        }
        let mut s = seq.task_to_rank.clone();
        s.sort_unstable();
        if s != (0..nt as u32).collect::<Vec<_>>() {
            return Err(format!("not a bijection ({intra:?})"));
        }
        Ok(())
    });
}

#[test]
fn prop_nontorus_hier_mapping_thread_invariant_and_bijective() {
    // The same determinism contract off the torus: the hierarchical
    // mapper on a fat-tree and a dragonfly must reproduce the sequential
    // result exactly at every thread budget and stay a bijection. This is
    // the end-to-end pin that the Topology abstraction did not smuggle
    // thread-count-dependent float ordering into the non-torus paths.
    use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
    use taskmap::machine::{Dragonfly, FatTree, Network, Topology};
    use taskmap::mapping::rotations::NativeBackend;
    let dense = |machine: Network, rpn: usize| {
        let nr = machine.num_routers();
        let mut core_router = Vec::with_capacity(nr * rpn);
        let mut core_node = Vec::with_capacity(nr * rpn);
        for r in 0..nr {
            for _ in 0..rpn {
                core_router.push(r as u32);
                core_node.push(r as u32);
            }
        }
        Allocation {
            machine,
            core_router,
            core_node,
            ranks_per_node: rpn,
        }
    };
    check("non-torus hier parallel == sequential", 6, |rng| {
        let rpn = rng.range(1, 4);
        let machine: Network = if rng.below(2) == 0 {
            FatTree::new(rng.range(2, 4), 2 + rng.below(2)).into()
        } else {
            Dragonfly::new(rng.range(2, 5), rng.range(2, 4), 1)
                .with_global_cost(1 + rng.below(3) as u64)
                .with_valiant(rng.below(2) == 1)
                .into()
        };
        let alloc = dense(machine, rpn);
        let nt = alloc.num_ranks();
        let graph = stencil_graph(&[nt], false, rng.f64_range(0.5, 3.0));
        let mk = |threads: usize| {
            let mut cfg = HierConfig {
                intra: IntraNodeStrategy::MinVolume { passes: 2 },
                max_rotations: 4,
                ..HierConfig::default()
            };
            cfg.spec.threads = threads;
            cfg
        };
        let seq = map_hierarchical(&graph, &graph.coords, &alloc, &mk(1), &NativeBackend);
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par = map_hierarchical(&graph, &graph.coords, &alloc, &mk(threads), &NativeBackend);
            if par.task_to_rank != seq.task_to_rank {
                return Err(format!(
                    "{} rank mapping diverged at threads={threads}",
                    alloc.machine.kind_name()
                ));
            }
        }
        let mut s = seq.task_to_rank.clone();
        s.sort_unstable();
        if s != (0..nt as u32).collect::<Vec<_>>() {
            return Err(format!("{} not a bijection", alloc.machine.kind_name()));
        }
        Ok(())
    });
}

#[test]
fn prop_routed_objective_sweep_parallel_bit_identical() {
    // Acceptance pin (a): congestion-objective scoring is bit-identical at
    // every thread count, through the full rotation sweep — same chosen
    // candidate, bit-equal scores, same mapping.
    use taskmap::mapping::rotations::{rotation_sweep, NativeBackend, SweepConfig};
    use taskmap::objective::ObjectiveKind;
    check("routed-objective sweep parallel == sequential", 8, |rng| {
        let tx = rng.range(2, 6);
        let ty = rng.range(2, 6);
        let n = tx * ty;
        let g = stencil_graph(&[tx, ty], rng.bool(), rng.f64_range(0.5, 4.0));
        let alloc = Allocation {
            machine: Torus::torus(&[ty, tx]).into(),
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        };
        let p = alloc.proc_coords();
        let map_cfg = MapConfig {
            task_ordering: random_ordering(rng),
            proc_ordering: random_ordering(rng),
            longest_dim: rng.bool(),
            uneven_prime: rng.bool(),
        };
        let objective = if rng.bool() {
            ObjectiveKind::MaxLinkLoad
        } else {
            ObjectiveKind::CongestionBlend
        };
        let sweep = |threads: usize| SweepConfig {
            max_candidates: 4,
            spec: MapSpec {
                threads,
                objective,
                ..MapSpec::default()
            },
            ..Default::default()
        };
        let seq = rotation_sweep(&g, &g.coords, &p, &alloc, &map_cfg, &sweep(1), &NativeBackend);
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par = rotation_sweep(
                &g,
                &g.coords,
                &p,
                &alloc,
                &map_cfg,
                &sweep(threads),
                &NativeBackend,
            );
            if par.chosen != seq.chosen || par.scores != seq.scores {
                return Err(format!("{objective:?}: scores diverged at threads={threads}"));
            }
            if par.task_to_rank != seq.task_to_rank {
                return Err(format!("{objective:?}: mapping diverged at threads={threads}"));
            }
        }
        // The winning score must equal the metrics engine's view of the
        // winning mapping (sweep and eval share the routing model).
        let m = eval_full(&g, &seq.task_to_rank, &alloc);
        let want = objective.value_from_metrics(&m);
        approx_eq(seq.scores[seq.chosen], want, 1e-9, 1e-9)
            .map_err(|e| format!("{objective:?}: sweep score vs eval_full: {e}"))
    });
}

#[test]
fn prop_hier_congestion_objective_parallel_bit_identical() {
    // The full two-level mapper under a routed objective — node sweep +
    // congestion MinVolume refinement — must be bit-identical at every
    // thread budget and still produce a bijection.
    use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
    use taskmap::mapping::rotations::NativeBackend;
    use taskmap::objective::ObjectiveKind;
    check("hier congestion parallel == sequential", 8, |rng| {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[5, 5, 5]),
            nodes_per_router: 2,
            ranks_per_node: rng.range(2, 5),
            occupancy: rng.f64_range(0.0, 0.3),
        }
        .allocate(rng.range(3, 9), rng.next_u64());
        let nt = alloc.num_ranks();
        let graph = stencil_graph(&[nt], false, rng.f64_range(0.5, 3.0));
        let objective = if rng.bool() {
            ObjectiveKind::MaxLinkLoad
        } else {
            ObjectiveKind::CongestionBlend
        };
        let mk = |threads: usize| HierConfig {
            intra: IntraNodeStrategy::MinVolume { passes: 3 },
            max_rotations: 4,
            spec: MapSpec {
                threads,
                objective,
                ..MapSpec::default()
            },
            ..HierConfig::default()
        };
        let seq = map_hierarchical(&graph, &graph.coords, &alloc, &mk(1), &NativeBackend);
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par = map_hierarchical(&graph, &graph.coords, &alloc, &mk(threads), &NativeBackend);
            if par.task_to_node != seq.task_to_node {
                return Err(format!("{objective:?}: node assignment diverged at threads={threads}"));
            }
            if par.task_to_rank != seq.task_to_rank {
                return Err(format!("{objective:?}: rank mapping diverged at threads={threads}"));
            }
            if par.swaps_applied != seq.swaps_applied {
                return Err(format!("{objective:?}: swap count diverged at threads={threads}"));
            }
        }
        let mut s = seq.task_to_rank.clone();
        s.sort_unstable();
        if s != (0..nt as u32).collect::<Vec<_>>() {
            return Err(format!("not a bijection under {objective:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_congestion_swap_gains_equal_full_reevaluation() {
    // Acceptance pin (b): every incremental swap gain equals the change in
    // a full eval_full re-evaluation of the induced node-level mapping.
    use taskmap::metrics::LinkAccumulator;
    use taskmap::objective::{CongestionState, ObjectiveKind};
    check("incremental gain == eval_full delta", 15, |rng| {
        let d = rng.range(1, 4);
        let sizes: Vec<usize> = (0..d).map(|_| rng.range(2, 6)).collect();
        let torus = Torus::torus(&sizes);
        let nn = rng.range(2, torus.num_routers().min(8) + 1);
        let routers: Vec<u32> = {
            let mut ids: Vec<u32> = (0..torus.num_routers() as u32).collect();
            rng.shuffle(&mut ids);
            ids.truncate(nn);
            ids
        };
        let nt = nn * rng.range(1, 5);
        let graph = stencil_graph(&[nt], rng.bool(), rng.f64_range(0.5, 5.0));
        let mut node_of: Vec<u32> = (0..nt).map(|t| (t % nn) as u32).collect();
        rng.shuffle(&mut node_of);
        // Adjacency lists for the gain entry point.
        let mut adj: Vec<Vec<(u32, f64)>> = vec![Vec::new(); nt];
        for e in &graph.edges {
            adj[e.u as usize].push((e.v, e.w));
            adj[e.v as usize].push((e.u, e.w));
        }
        // The node-level pseudo-allocation eval_full scores against.
        let alloc = Allocation {
            machine: torus.clone().into(),
            core_router: routers.clone(),
            core_node: (0..nn as u32).collect(),
            ranks_per_node: 1,
        };
        let kind = if rng.bool() {
            ObjectiveKind::MaxLinkLoad
        } else {
            ObjectiveKind::CongestionBlend
        };
        let mut state = CongestionState::build(&torus, &routers, &graph, &node_of, kind);
        let mut acc = LinkAccumulator::new(&torus);
        for _ in 0..8 {
            let u = rng.below(nt);
            let b = rng.below(nt);
            if u == b || node_of[u] == node_of[b] {
                continue;
            }
            let before = kind.value_from_metrics(&eval_full(&graph, &node_of, &alloc));
            let gain = state.swap_gain(
                &node_of,
                u,
                b,
                adj[u].iter().copied(),
                adj[b].iter().copied(),
                &mut acc,
            );
            state.commit(&acc);
            node_of.swap(u, b);
            let after = kind.value_from_metrics(&eval_full(&graph, &node_of, &alloc));
            approx_eq(gain, before - after, 1e-9, 1e-9)
                .map_err(|e| format!("{kind:?}: gain vs eval_full delta: {e}"))?;
            approx_eq(state.value(), after, 1e-9, 1e-9)
                .map_err(|e| format!("{kind:?}: state value vs eval_full: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_numa_depth3_parallel_bit_identical_and_bijective() {
    // The full three-level mapper — NUMA node sweep, NUMA MinVolume
    // refinement, socket split, cross-socket refinement, socket-aware
    // placement — must reproduce the sequential result exactly at every
    // thread budget, produce a bijection when tnum == ranks, and respect
    // both the node and the position-derived socket assignment.
    use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
    use taskmap::machine::NumaTopology;
    use taskmap::mapping::rotations::NativeBackend;
    check("numa depth-3 parallel == sequential", 8, |rng| {
        let sockets = rng.range(1, 3);
        let rps = rng.range(1, 4);
        let alloc = SparseAllocator {
            machine: Torus::torus(&[5, 5, 5]),
            nodes_per_router: 2,
            ranks_per_node: sockets * rps,
            occupancy: rng.f64_range(0.0, 0.3),
        }
        .allocate(rng.range(3, 9), rng.next_u64());
        let topo = NumaTopology::new(sockets, rps, rng.f64_range(0.2, 0.8), 0.0, 1.0);
        let nt = alloc.num_ranks();
        let graph = stencil_graph(&[nt], false, rng.f64_range(0.5, 3.0));
        let intra = match rng.below(3) {
            0 => IntraNodeStrategy::DefaultOrder,
            1 => IntraNodeStrategy::SfcOrder,
            _ => IntraNodeStrategy::MinVolume { passes: 3 },
        };
        let mk = |threads: usize| HierConfig {
            intra,
            max_rotations: 4,
            spec: MapSpec {
                threads,
                numa: Some(topo),
                ..MapSpec::default()
            },
            ..HierConfig::default()
        };
        let seq = map_hierarchical(&graph, &graph.coords, &alloc, &mk(1), &NativeBackend);
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par = map_hierarchical(&graph, &graph.coords, &alloc, &mk(threads), &NativeBackend);
            if par.task_to_node != seq.task_to_node {
                return Err(format!("node assignment diverged at threads={threads}"));
            }
            if par.task_to_socket != seq.task_to_socket {
                return Err(format!("socket assignment diverged at threads={threads}"));
            }
            if par.task_to_rank != seq.task_to_rank {
                return Err(format!("rank mapping diverged at threads={threads}"));
            }
            if (par.swaps_applied, par.socket_swaps) != (seq.swaps_applied, seq.socket_swaps) {
                return Err(format!("swap counts diverged at threads={threads}"));
            }
        }
        let mut s = seq.task_to_rank.clone();
        s.sort_unstable();
        if s != (0..nt as u32).collect::<Vec<_>>() {
            return Err(format!("not a bijection ({intra:?})"));
        }
        let socks = seq.task_to_socket.as_ref().expect("depth 3 reports sockets");
        let rank_socks = topo.socket_of_ranks(&alloc);
        for t in 0..nt {
            let rank = seq.task_to_rank[t] as usize;
            if alloc.core_node[rank] != seq.task_to_node[t] {
                return Err(format!("task {t} violates its node assignment"));
            }
            if rank_socks[rank] != socks[t] {
                return Err(format!("task {t} violates its socket assignment"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hetero_depth3_balanced_and_bit_identical() {
    // Heterogeneous ranks-per-node allocations: the node-level partition
    // must hand every node exactly its rank count (capacity balance), the
    // intra-node placement must stay a node/socket-respecting bijection,
    // and the whole depth-3 pipeline must be bit-identical at 1/2/8
    // threads.
    use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
    use taskmap::machine::NumaTopology;
    use taskmap::mapping::rotations::NativeBackend;
    check("hetero depth-3 balance + determinism", 8, |rng| {
        let torus = Torus::torus(&[5, 5, 5]);
        let nn = rng.range(3, 7);
        let routers: Vec<u32> = (0..nn)
            .map(|_| rng.below(torus.num_routers()) as u32)
            .collect();
        let sizes: Vec<usize> = (0..nn).map(|_| rng.range(1, 7)).collect();
        let alloc = Allocation::heterogeneous(torus, &routers, &sizes)
            .map_err(|e| format!("constructor: {e}"))?;
        let sockets = rng.range(1, 3);
        let rps = rng.range(1, 4);
        let topo = NumaTopology::new(sockets, rps, rng.f64_range(0.2, 0.8), 0.0, 1.0);
        let nt = alloc.num_ranks();
        let graph = stencil_graph(&[nt], false, rng.f64_range(0.5, 3.0));
        let intra = match rng.below(3) {
            0 => IntraNodeStrategy::DefaultOrder,
            1 => IntraNodeStrategy::SfcOrder,
            _ => IntraNodeStrategy::MinVolume { passes: 3 },
        };
        let mk = |threads: usize| HierConfig {
            intra,
            max_rotations: 4,
            spec: MapSpec {
                threads,
                numa: Some(topo),
                ..MapSpec::default()
            },
            ..HierConfig::default()
        };
        let seq = map_hierarchical(&graph, &graph.coords, &alloc, &mk(1), &NativeBackend);
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par = map_hierarchical(&graph, &graph.coords, &alloc, &mk(threads), &NativeBackend);
            if (&par.task_to_node, &par.task_to_socket, &par.task_to_rank)
                != (&seq.task_to_node, &seq.task_to_socket, &seq.task_to_rank)
            {
                return Err(format!("diverged at threads={threads} (sizes {sizes:?})"));
            }
        }
        // Capacity balance: node n receives exactly sizes[n] tasks.
        let mut per_node = vec![0usize; nn];
        for &n in &seq.task_to_node {
            per_node[n as usize] += 1;
        }
        if per_node != sizes {
            return Err(format!("per-node counts {per_node:?} != sizes {sizes:?}"));
        }
        // Bijection + node/socket respect.
        let mut s = seq.task_to_rank.clone();
        s.sort_unstable();
        if s != (0..nt as u32).collect::<Vec<_>>() {
            return Err("not a bijection".into());
        }
        let socks = seq.task_to_socket.as_ref().unwrap();
        let rank_socks = topo.socket_of_ranks(&alloc);
        for t in 0..nt {
            let rank = seq.task_to_rank[t] as usize;
            if alloc.core_node[rank] != seq.task_to_node[t]
                || rank_socks[rank] != socks[t]
            {
                return Err(format!("task {t} violates node/socket assignment"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_blended_incremental_gain_equals_full_eval() {
    // Acceptance pin (a): for EVERY evaluator combination — hop or routed
    // network term, with or without the NUMA intra-node term — the
    // incremental swap gain equals a from-scratch full_eval delta, and
    // the cached value tracks full_eval across commits.
    use taskmap::machine::NumaNodeCosts;
    use taskmap::objective::{
        build_eval, Adjacency, EvalScratch, EvalSpec, IncrementalEval, ObjectiveKind,
    };
    check("blended incremental gain == full eval", 12, |rng| {
        let d = rng.range(1, 4);
        let sizes: Vec<usize> = (0..d).map(|_| rng.range(2, 6)).collect();
        let torus = Torus::torus(&sizes);
        let nn = rng.range(2, torus.num_routers().min(8) + 1);
        let routers: Vec<u32> = {
            let mut ids: Vec<u32> = (0..torus.num_routers() as u32).collect();
            rng.shuffle(&mut ids);
            ids.truncate(nn);
            ids
        };
        let nt = nn * rng.range(1, 5);
        let graph = stencil_graph(&[nt], rng.bool(), rng.f64_range(0.5, 5.0));
        let adj = Adjacency::build(&graph);
        let objective = match rng.below(3) {
            0 => ObjectiveKind::WeightedHops,
            1 => ObjectiveKind::MaxLinkLoad,
            _ => ObjectiveKind::CongestionBlend,
        };
        let numa = if rng.bool() {
            Some(NumaNodeCosts {
                // Routed objectives require hop == 1; WeightedHops may
                // scale it.
                hop: if objective == ObjectiveKind::WeightedHops {
                    rng.f64_range(0.5, 2.0)
                } else {
                    1.0
                },
                socket: rng.f64_range(0.1, 0.9),
            })
        } else {
            None
        };
        let spec = EvalSpec::new(objective, numa);
        spec.validate().map_err(|e| format!("spec invalid: {e}"))?;
        let mut node_of: Vec<u32> = (0..nt).map(|t| (t % nn) as u32).collect();
        rng.shuffle(&mut node_of);
        let mut eval = build_eval(&torus, &routers, &graph, &node_of, spec);
        let mut scratch = EvalScratch::new();
        for _ in 0..8 {
            let u = rng.below(nt);
            let b = rng.below(nt);
            if u == b || node_of[u] == node_of[b] {
                continue;
            }
            let before = eval.full_eval(&graph, &node_of);
            let ev = eval.swap_eval(&node_of, &adj, u, b, &mut scratch);
            eval.commit(&ev, &scratch);
            node_of.swap(u, b);
            let after = eval.full_eval(&graph, &node_of);
            approx_eq(ev.gain, before - after, 1e-9, 1e-9)
                .map_err(|e| format!("{}: gain vs full_eval delta: {e}", spec.name()))?;
            approx_eq(eval.value(), after, 1e-9, 1e-9)
                .map_err(|e| format!("{}: cached value vs full_eval: {e}", spec.name()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_blended_depth3_parallel_bit_identical() {
    // Acceptance pin (b): the blended (routed congestion x NUMA) depth-3
    // pipeline — node sweep, blended MinVolume refinement, socket
    // split/refinement, socket-aware placement — is bit-identical at
    // every thread budget, on uniform AND heterogeneous allocations, and
    // still produces a node/socket-respecting bijection.
    use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
    use taskmap::machine::NumaTopology;
    use taskmap::mapping::rotations::NativeBackend;
    use taskmap::objective::ObjectiveKind;
    check("blended depth-3 parallel == sequential", 8, |rng| {
        let sockets = rng.range(1, 3);
        let rps = rng.range(1, 4);
        let hetero = rng.bool();
        let alloc = if hetero {
            let torus = Torus::torus(&[5, 5, 5]);
            let nn = rng.range(3, 7);
            let routers: Vec<u32> = (0..nn)
                .map(|_| rng.below(torus.num_routers()) as u32)
                .collect();
            let sizes: Vec<usize> = (0..nn).map(|_| rng.range(1, 7)).collect();
            Allocation::heterogeneous(torus, &routers, &sizes)
                .map_err(|e| format!("constructor: {e}"))?
        } else {
            SparseAllocator {
                machine: Torus::torus(&[5, 5, 5]),
                nodes_per_router: 2,
                ranks_per_node: sockets * rps,
                occupancy: rng.f64_range(0.0, 0.3),
            }
            .allocate(rng.range(3, 9), rng.next_u64())
        };
        let topo = NumaTopology::new(sockets, rps, rng.f64_range(0.2, 0.8), 0.0, 1.0);
        let nt = alloc.num_ranks();
        let graph = stencil_graph(&[nt], false, rng.f64_range(0.5, 3.0));
        let objective = if rng.bool() {
            ObjectiveKind::MaxLinkLoad
        } else {
            ObjectiveKind::CongestionBlend
        };
        let intra = match rng.below(3) {
            0 => IntraNodeStrategy::DefaultOrder,
            1 => IntraNodeStrategy::SfcOrder,
            _ => IntraNodeStrategy::MinVolume { passes: 3 },
        };
        let mk = |threads: usize| HierConfig {
            intra,
            max_rotations: 4,
            spec: MapSpec {
                threads,
                objective,
                numa: Some(topo),
                ..MapSpec::default()
            },
            ..HierConfig::default()
        };
        let seq = map_hierarchical(&graph, &graph.coords, &alloc, &mk(1), &NativeBackend);
        for &threads in THREAD_COUNTS.iter().skip(1) {
            let par = map_hierarchical(&graph, &graph.coords, &alloc, &mk(threads), &NativeBackend);
            if par.task_to_node != seq.task_to_node {
                return Err(format!(
                    "{objective:?} hetero={hetero}: node assignment diverged at threads={threads}"
                ));
            }
            if par.task_to_socket != seq.task_to_socket {
                return Err(format!(
                    "{objective:?} hetero={hetero}: socket assignment diverged at threads={threads}"
                ));
            }
            if par.task_to_rank != seq.task_to_rank {
                return Err(format!(
                    "{objective:?} hetero={hetero}: rank mapping diverged at threads={threads}"
                ));
            }
            if (par.swaps_applied, par.socket_swaps) != (seq.swaps_applied, seq.socket_swaps) {
                return Err(format!(
                    "{objective:?} hetero={hetero}: swap counts diverged at threads={threads}"
                ));
            }
        }
        let mut s = seq.task_to_rank.clone();
        s.sort_unstable();
        if s != (0..nt as u32).collect::<Vec<_>>() {
            return Err(format!("not a bijection ({objective:?}, {intra:?})"));
        }
        let socks = seq.task_to_socket.as_ref().expect("depth 3 reports sockets");
        let rank_socks = topo.socket_of_ranks(&alloc);
        for t in 0..nt {
            let rank = seq.task_to_rank[t] as usize;
            if alloc.core_node[rank] != seq.task_to_node[t] || rank_socks[rank] != socks[t] {
                return Err(format!("task {t} violates node/socket assignment"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_numa_swap_gains_equal_full_reevaluation() {
    // Acceptance pin: the NumaAware incremental placement swap gain equals
    // the delta of a full eval_numa_placement re-evaluation, for same-node
    // (socket-only) and cross-node swaps alike.
    use taskmap::machine::NumaTopology;
    use taskmap::objective::{eval_numa_placement, placement_swap_gain};
    check("numa incremental gain == full re-eval", 15, |rng| {
        let d = rng.range(1, 4);
        let sizes: Vec<usize> = (0..d).map(|_| rng.range(2, 6)).collect();
        let torus = Torus::torus(&sizes);
        let nn = rng.range(2, torus.num_routers().min(8) + 1);
        let routers: Vec<u32> = {
            let mut ids: Vec<u32> = (0..torus.num_routers() as u32).collect();
            rng.shuffle(&mut ids);
            ids.truncate(nn);
            ids
        };
        let sockets = rng.range(1, 4);
        let core = rng.f64_range(0.0, 0.3);
        let topo = NumaTopology::new(
            sockets,
            rng.range(1, 5),
            core + rng.f64_range(0.0, 1.0),
            core,
            rng.f64_range(0.5, 2.0),
        );
        let nt = nn * rng.range(1, 5);
        let graph = stencil_graph(&[nt], rng.bool(), rng.f64_range(0.5, 5.0));
        let mut node_of: Vec<u32> = (0..nt).map(|t| (t % nn) as u32).collect();
        rng.shuffle(&mut node_of);
        let mut sock_of: Vec<u32> =
            (0..nt).map(|_| rng.below(sockets) as u32).collect();
        let mut adj: Vec<Vec<(u32, f64)>> = vec![Vec::new(); nt];
        for e in &graph.edges {
            adj[e.u as usize].push((e.v, e.w));
            adj[e.v as usize].push((e.u, e.w));
        }
        for _ in 0..8 {
            let u = rng.below(nt);
            let b = rng.below(nt);
            if u == b {
                continue;
            }
            let before =
                eval_numa_placement(&graph, &node_of, &sock_of, &routers, &torus, &topo);
            let gain = placement_swap_gain(
                &topo,
                &torus,
                &routers,
                &node_of,
                &sock_of,
                u,
                b,
                adj[u].iter().copied(),
                adj[b].iter().copied(),
            );
            node_of.swap(u, b);
            sock_of.swap(u, b);
            let after =
                eval_numa_placement(&graph, &node_of, &sock_of, &routers, &torus, &topo);
            approx_eq(gain, before.value - after.value, 1e-9, 1e-9)
                .map_err(|e| format!("swap ({u},{b}): {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_intra_node_edges_cost_nothing() {
    // Node-boundary contract: any graph whose edges connect only ranks of
    // the same node reports zero hops, zero messages, and zero link data,
    // for both eval paths.
    use taskmap::apps::{Edge, TaskGraph};
    use taskmap::geom::Coords;
    check("intra-node edges are free", 20, |rng| {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[rng.range(3, 7), rng.range(3, 7), rng.range(3, 7)]),
            nodes_per_router: 2,
            ranks_per_node: rng.range(2, 9),
            occupancy: rng.f64_range(0.0, 0.4),
        }
        .allocate(rng.range(2, 12), rng.next_u64());
        let nt = alloc.num_ranks();
        // Random edges drawn within nodes only (identity mapping).
        let mut edges = Vec::new();
        for group in alloc.ranks_by_node() {
            for w in group.windows(2) {
                edges.push(Edge {
                    u: w[0],
                    v: w[1],
                    w: rng.f64_range(0.5, 10.0),
                });
            }
        }
        let graph = TaskGraph {
            num_tasks: nt,
            edges,
            coords: Coords::from_axes(vec![vec![0.0; nt]]),
        };
        let mapping: Vec<u32> = (0..nt as u32).collect();
        let cheap = eval_hops(&graph, &mapping, &alloc);
        let full = eval_full(&graph, &mapping, &alloc);
        if cheap.total_hops != 0.0 || cheap.weighted_hops != 0.0 || cheap.total_messages != 0 {
            return Err(format!("eval_hops saw network traffic: {cheap:?}"));
        }
        if full.total_hops != 0.0 || full.total_messages != 0 {
            return Err(format!("eval_full saw network traffic: {full:?}"));
        }
        let lm = full.link.as_ref().unwrap();
        if lm.max_data != 0.0 || lm.avg_data != 0.0 || lm.max_latency != 0.0 {
            return Err(format!("link data on intra-node edges: {lm:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_coarsen_bit_identical_across_threads() {
    // The propose-parallel / apply-sequential matching must produce the
    // exact same hierarchy at every thread count: same projections, same
    // matched counts, bit-equal coarse weights, coordinates, and edges.
    // The tiny grain forces real parallel splits on these small inputs.
    use taskmap::coarsen::{coarsen, CoarsenConfig, MatchingKind};
    use taskmap::par::Parallelism;
    use taskmap::testutil::graphs::random_sparse;
    check("coarsen parallel == sequential", 12, |rng| {
        let n = rng.range(40, 400);
        let g = random_sparse(n, rng.range(1, 4), rng.range(2, 5), rng.next_u64());
        let cfg = CoarsenConfig {
            target_tasks: rng.range(4, 24),
            max_levels: rng.range(1, 8),
            matching: if rng.bool() {
                MatchingKind::HeavyEdge
            } else {
                MatchingKind::Geometric
            },
        };
        let seq = coarsen(
            g.num_tasks,
            &g.edges,
            &g.coords,
            cfg,
            Parallelism::sequential(),
        );
        for &threads in THREAD_COUNTS.iter() {
            let par = coarsen(
                g.num_tasks,
                &g.edges,
                &g.coords,
                cfg,
                Parallelism::threads(threads).with_grain(1),
            );
            if par.num_levels() != seq.num_levels() {
                return Err(format!(
                    "level count {} != {} at threads={threads} (n={n})",
                    par.num_levels(),
                    seq.num_levels()
                ));
            }
            for (l, (a, b)) in par.levels.iter().zip(seq.levels.iter()).enumerate() {
                if a.fine_to_coarse != b.fine_to_coarse
                    || a.matched != b.matched
                    || a.weights != b.weights
                    || a.graph.num_tasks != b.graph.num_tasks
                    || a.graph.edges != b.graph.edges
                {
                    return Err(format!("level {l} diverged at threads={threads} (n={n})"));
                }
                for d in 0..a.graph.coords.dim() {
                    if a.graph.coords.axis(d) != b.graph.coords.axis(d) {
                        return Err(format!(
                            "level {l} coords diverged at threads={threads} (n={n})"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_coarsen_projection_round_trips_exactly() {
    // restrict(project(x)) == x bit for bit, for any coarsest-level
    // labeling — projection and restriction are pure indexing, so the
    // round trip must be exact, never merely approximate.
    use taskmap::coarsen::{coarsen, CoarsenConfig};
    use taskmap::par::Parallelism;
    use taskmap::testutil::graphs::random_sparse;
    check("restrict(project(x)) == x", 16, |rng| {
        let n = rng.range(40, 400);
        let g = random_sparse(n, rng.range(1, 4), rng.range(2, 5), rng.next_u64());
        let cfg = CoarsenConfig {
            target_tasks: rng.range(2, 16),
            ..CoarsenConfig::default()
        };
        let h = coarsen(
            g.num_tasks,
            &g.edges,
            &g.coords,
            cfg,
            Parallelism::sequential(),
        );
        let Some(coarsest) = h.coarsest() else {
            return Ok(()); // nothing contracted: nothing to round-trip
        };
        let x: Vec<u32> = (0..coarsest.graph.num_tasks)
            .map(|_| rng.below(64) as u32)
            .collect();
        let fine = h.project(&x);
        if fine.len() != g.num_tasks {
            return Err(format!(
                "projection has {} entries for {} tasks",
                fine.len(),
                g.num_tasks
            ));
        }
        let back = h.restrict(&fine);
        if back != x {
            return Err(format!("round trip diverged (n={n}, levels={})", h.num_levels()));
        }
        Ok(())
    });
}

#[test]
fn prop_vcycle_mapping_thread_invariant_and_balanced() {
    // The full V-cycle mapping (coarsen -> coarsest sweep -> uncoarsen
    // with rebalance + refinement -> rank placement) is bit-identical at
    // every thread count, respects the node structure, and lands the
    // exact count-balanced per-node distribution of the direct sweep.
    use taskmap::coarsen::CoarsenConfig;
    use taskmap::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
    use taskmap::mapping::rotations::NativeBackend;
    use taskmap::testutil::graphs::random_sparse;
    check("vcycle thread-invariant", 6, |rng| {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[rng.range(3, 6), rng.range(3, 6), rng.range(3, 6)]),
            nodes_per_router: 2,
            ranks_per_node: rng.range(2, 5),
            occupancy: rng.f64_range(0.0, 0.4),
        }
        .allocate(rng.range(4, 9), rng.next_u64());
        let nn = alloc.num_nodes();
        let tnum = nn * rng.range(4, 7);
        let g = random_sparse(tnum, rng.range(1, 4), 3, rng.next_u64());
        let cfg = |threads: usize| HierConfig {
            intra: IntraNodeStrategy::MinVolume { passes: 2 },
            max_rotations: 2,
            spec: MapSpec {
                threads,
                coarsen: Some(CoarsenConfig {
                    target_tasks: nn,
                    ..CoarsenConfig::default()
                }),
                ..MapSpec::default()
            },
            ..HierConfig::default()
        };
        let seq = map_hierarchical(&g, &g.coords, &alloc, &cfg(1), &NativeBackend);
        if seq.coarsen_levels.is_empty() {
            return Err(format!("expected the V-cycle path (tnum={tnum} nn={nn})"));
        }
        // Node structure and exact count balance.
        let mut counts = vec![0usize; nn];
        for t in 0..tnum {
            let rank = seq.task_to_rank[t] as usize;
            let node = seq.task_to_node[t] as usize;
            if rank / alloc.ranks_per_node != node {
                return Err(format!("task {t}: rank {rank} not on node {node}"));
            }
            counts[node] += 1;
        }
        for (n, &c) in counts.iter().enumerate() {
            let want = (n + 1) * tnum / nn - n * tnum / nn;
            if c != want {
                return Err(format!("node {n}: {c} tasks != {want} (tnum={tnum})"));
            }
        }
        for &threads in THREAD_COUNTS.iter() {
            let par = map_hierarchical(&g, &g.coords, &alloc, &cfg(threads), &NativeBackend);
            if par.task_to_rank != seq.task_to_rank
                || par.task_to_node != seq.task_to_node
                || par.coarsen_levels != seq.coarsen_levels
                || par.swaps_applied != seq.swaps_applied
            {
                return Err(format!("mapping diverged at threads={threads} (tnum={tnum})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_allocation_ranks_consistent() {
    check("allocation consistency", 20, |rng| {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[rng.range(4, 10), rng.range(4, 10), rng.range(4, 10)]),
            nodes_per_router: 2,
            ranks_per_node: rng.range(1, 17),
            occupancy: rng.f64_range(0.0, 0.5),
        };
        let nodes = rng.range(2, 20);
        let a = alloc.allocate(nodes, rng.next_u64());
        if a.num_nodes() != nodes {
            return Err(format!("{} != {nodes} nodes", a.num_nodes()));
        }
        for w in a.core_node.windows(2) {
            if w[1] < w[0] {
                return Err("node ids must be nondecreasing in rank order".into());
            }
        }
        // All ranks of a node share a router.
        for r in 0..a.num_ranks() {
            let n = a.core_node[r] as usize;
            let first = a.core_router[n * alloc.ranks_per_node] ;
            if a.core_router[r] != first {
                return Err(format!("rank {r}: router differs within node {n}"));
            }
        }
        Ok(())
    });
}
