//! End-to-end driver (DESIGN.md deliverable b / EXPERIMENTS.md headline):
//! the full MiniGhost weak-scaling study of Section 5.3.2 — workload
//! generation, sparse ALPS-style allocation, all five mapping strategies
//! (Default, Group, Z2_1, Z2_2, Z2_3), metrics, and simulated communication
//! time — exercising every layer including the PJRT-backed rotation sweep
//! when artifacts are present.
//!
//! ```bash
//! make artifacts && cargo run --release --example minighost_weak_scaling
//! cargo run --release --example minighost_weak_scaling -- --small
//! ```

use taskmap::apps::minighost::MiniGhost;
use taskmap::coordinator::report::Table;
use taskmap::machine::{cray_xk7, titan_full, SparseAllocator};
use taskmap::mapping::pipeline::{z2_map, Z2Config};
use taskmap::mapping::rotations::{NativeBackend, WhopsBackend};
use taskmap::metrics::eval_full;
use taskmap::runtime::PjrtBackend;
use taskmap::simulate::{comm_time, CommModel};

fn main() {
    let small = std::env::args().any(|a| a == "--small");
    let seed = 42u64;
    // Backend: PJRT artifacts if built, else native (and say which).
    let pjrt = PjrtBackend::try_default();
    let backend: &dyn WhopsBackend = match &pjrt {
        Some(b) => b,
        None => &NativeBackend,
    };
    eprintln!("WeightedHops backend: {}", backend.name());

    let (points, allocator): (Vec<(usize, [usize; 3])>, SparseAllocator) = if small {
        (
            vec![(512, [8, 8, 8]), (1024, [16, 8, 8]), (2048, [16, 16, 8])],
            SparseAllocator {
                machine: cray_xk7(&[10, 8, 10]),
                nodes_per_router: 2,
                ranks_per_node: 16,
                occupancy: 0.4,
            },
        )
    } else {
        (
            vec![
                (8_192, [32, 16, 16]),
                (16_384, [32, 32, 16]),
                (32_768, [32, 32, 32]),
            ],
            titan_full(),
        )
    };

    let model = CommModel {
        rounds: 20.0, // 20 timesteps, as in the paper
        ..Default::default()
    };
    let mut cfgs: Vec<(&str, Option<Z2Config>)> = vec![("Default", None), ("Group", None)];
    for (name, mut cfg) in [
        ("Z2_1", Z2Config::z2_1()),
        ("Z2_2", Z2Config::z2_2()),
        ("Z2_3", Z2Config::z2_3()),
    ] {
        cfg.max_rotations = 12;
        cfgs.push((name, Some(cfg)));
    }

    let mut time_table = Table::new(
        "MiniGhost weak scaling: max communication time (s)",
        &["procs", "Default", "Group", "Z2_1", "Z2_2", "Z2_3"],
    );
    let mut hops_table = Table::new(
        "MiniGhost weak scaling: AverageHops",
        &["procs", "Default", "Group", "Z2_1", "Z2_2", "Z2_3"],
    );
    for &(procs, tdims) in &points {
        let mg = MiniGhost::weak_scaling(tdims);
        let graph = mg.graph();
        let alloc = allocator.allocate(procs / 16, seed);
        let mut times = vec![procs.to_string()];
        let mut hops = vec![procs.to_string()];
        for (name, cfg) in &cfgs {
            let start = std::time::Instant::now();
            let mapping = match (name, cfg) {
                (&"Default", _) => mg.default_order(),
                (&"Group", _) => mg.group_order(),
                (_, Some(cfg)) => z2_map(&graph, &graph.coords, &alloc, cfg, backend),
                _ => unreachable!(),
            };
            let t = comm_time(&graph, &mapping, &alloc, &model);
            let m = eval_full(&graph, &mapping, &alloc);
            times.push(format!("{:.4}", t.total));
            hops.push(format!("{:.2}", m.avg_hops));
            eprintln!(
                "  [{procs:>6} procs] {name:<8} comm={:.4}s hops={:.2} (mapped in {:.2}s)",
                t.total,
                m.avg_hops,
                start.elapsed().as_secs_f64()
            );
        }
        time_table.push_row(times);
        hops_table.push_row(hops);
    }
    println!("{}", time_table.markdown());
    println!("{}", hops_table.markdown());

    // Headline: reduction of Z2_1 vs Default at the largest scale.
    let last = time_table.rows.last().unwrap();
    let default: f64 = last[1].parse().unwrap();
    let z2: f64 = last[3].parse().unwrap();
    println!(
        "headline: Z2 reduces MiniGhost communication time by {:.0}% vs Default \
         at {} procs (paper: 35-64% on real hardware)",
        (1.0 - z2 / default) * 100.0,
        last[0]
    );
    if let Some(b) = &pjrt {
        println!(
            "PJRT executions: {} (fallbacks: {})",
            b.runtime.executions.lock().unwrap(),
            b.fallbacks.lock().unwrap()
        );
    }
}
