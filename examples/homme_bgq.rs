//! HOMME on BlueGene/Q (Section 5.2): cube-sphere workload, contiguous
//! block allocation, SFC vs SFC+Z2 vs Z2 with the coordinate transforms of
//! Fig. 7 (Sphere / Cube / 2DFace) and the "+E" optimization.
//!
//! ```bash
//! cargo run --release --example homme_bgq            # ne=32, 512 ranks
//! cargo run --release --example homme_bgq -- --small # ne=16, 128 ranks
//! ```

use taskmap::apps::homme::{Homme, HommeCoords};
use taskmap::coordinator::report::Table;
use taskmap::machine::{bgq_block, Allocation};
use taskmap::mapping::pipeline::{sfc_plus_z2, z2_map, Z2Config};
use taskmap::mapping::rotations::{NativeBackend, WhopsBackend};
use taskmap::metrics::eval_full;
use taskmap::runtime::PjrtBackend;
use taskmap::simulate::{comm_time, CommModel};

fn main() {
    let small = std::env::args().any(|a| a == "--small");
    let (ne, nodes, rpn) = if small { (16, 32, 4) } else { (32, 128, 4) };
    let pjrt = PjrtBackend::try_default();
    let backend: &dyn WhopsBackend = match &pjrt {
        Some(b) => b,
        None => &NativeBackend,
    };
    eprintln!("backend: {}", backend.name());

    let homme = Homme::new(ne);
    let graph = homme.graph();
    let alloc = Allocation::bgq(bgq_block(nodes), rpn, "ABCDET").expect("valid rank order");
    println!(
        "HOMME: {} elements on a cube-sphere (ne={ne}); BG/Q block {:?}, {} ranks\n",
        homme.num_tasks(),
        alloc.torus.sizes,
        alloc.num_ranks()
    );

    let model = CommModel {
        rounds: 100.0,
        ..Default::default()
    };
    let sfc = homme.sfc_partition(alloc.num_ranks());
    let t_sfc = comm_time(&graph, &sfc, &alloc, &model).total;

    let mut table = Table::new(
        "HOMME BG/Q: strategies vs transforms (time normalized to SFC)",
        &["strategy", "coords", "+E", "time/SFC", "AvgHops", "Data(M)/SFC"],
    );
    let m_sfc = eval_full(&graph, &sfc, &alloc);
    let sfc_data = m_sfc.link.as_ref().unwrap().max_data;
    table.push_row(vec![
        "SFC".into(),
        "-".into(),
        "-".into(),
        "1.00".into(),
        format!("{:.2}", m_sfc.avg_hops),
        "1.00".into(),
    ]);
    for coords in [HommeCoords::Sphere, HommeCoords::Cube, HommeCoords::Face2D] {
        for plus_e in [false, true] {
            let mut cfg = Z2Config::z2_1();
            cfg.max_rotations = 8;
            if plus_e {
                cfg = cfg.plus_e();
            }
            let tcoords = homme.coords(coords);
            for (label, mapping) in [
                (
                    "SFC+Z2",
                    sfc_plus_z2(
                        &graph,
                        &tcoords,
                        &sfc,
                        alloc.num_ranks(),
                        &alloc,
                        &cfg,
                        backend,
                    ),
                ),
                ("Z2", z2_map(&graph, &tcoords, &alloc, &cfg, backend)),
            ] {
                let t = comm_time(&graph, &mapping, &alloc, &model).total;
                let m = eval_full(&graph, &mapping, &alloc);
                table.push_row(vec![
                    label.into(),
                    coords.name().into(),
                    if plus_e { "yes" } else { "no" }.into(),
                    format!("{:.2}", t / t_sfc),
                    format!("{:.2}", m.avg_hops),
                    format!("{:.2}", m.link.unwrap().max_data / sfc_data),
                ]);
            }
        }
    }
    println!("{}", table.markdown());
    println!(
        "paper shape: Z2 gains appear at scale (16K/32K ranks: 20-27%); at small\n\
         scale SFC is already good. Data(M) reduction drives the gains (Fig 9)."
    );
}
