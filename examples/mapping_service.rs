//! Mapping service demo: start the TCP mapping daemon, connect as a
//! client, and request a mapping over the wire — the deployment shape where
//! a job launcher asks a central service for rank placements.
//!
//! ```bash
//! cargo run --release --example mapping_service            # demo mode
//! cargo run --release --example mapping_service -- --serve # daemon mode
//! ```

use taskmap::coordinator::service::{request_with_retry, Client, RetryPolicy, Service};
use taskmap::sfc::PartOrdering;
use taskmap::testutil::json::Json;

fn main() {
    let serve_only = std::env::args().any(|a| a == "--serve");
    let svc = Service::start("127.0.0.1:0").expect("bind");
    println!("mapping service on {}", svc.addr);
    if serve_only {
        println!("daemon mode; Ctrl-C to stop");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    // Demo: a 4x4 task grid onto a reversed 4x4 processor grid.
    let mut client = Client::connect(svc.addr).expect("connect");
    let tasks: Vec<Vec<f64>> = (0..16)
        .map(|i| vec![(i % 4) as f64, (i / 4) as f64])
        .collect();
    let procs: Vec<Vec<f64>> = (0..16)
        .map(|i| vec![(3 - i % 4) as f64, (3 - i / 4) as f64])
        .collect();
    let mapping = client
        .map(&tasks, &procs, PartOrdering::FZ)
        .expect("map request");
    println!("\ntask -> rank (geometric FZ mapping over the wire):");
    for (t, r) in mapping.iter().enumerate() {
        print!("{t:>3}->{r:<3}");
        if t % 4 == 3 {
            println!();
        }
    }
    // Sanity: bijection.
    let mut s = mapping.clone();
    s.sort_unstable();
    assert_eq!(s, (0..16).collect::<Vec<u32>>());
    println!("\nbijection verified.");

    // NUMA depth-3: a chain of 8 tasks onto 2 nodes x 2 ranks, where each
    // node is 2 sockets of 1 rank — the "numa" field turns on the
    // socket-level split and the response reports each task's socket.
    // "profile": true additionally returns a per-phase latency breakdown
    // and a trace id that the trace endpoint below can correlate.
    let numa_req = Json::parse(
        r#"{"op":"map",
            "tcoords":[[0],[1],[2],[3],[4],[5],[6],[7]],
            "pcoords":[[0],[0],[1],[1]],
            "edges":[[0,1],[1,2],[2,3],[3,4],[4,5],[5,6],[6,7]],
            "hier":{"ranks_per_node":2,"strategy":"minvol"},
            "numa":{"sockets_per_node":2,"ranks_per_socket":1,"socket_cost":0.5},
            "profile":true}"#,
    )
    .expect("static request parses");
    let resp = client.request(&numa_req).expect("numa map request");
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    println!("\ndepth-3 (NUMA) mapping over the wire:");
    println!("  map:     {}", resp.get("map").unwrap().to_string());
    println!("  nodes:   {}", resp.get("nodes").unwrap().to_string());
    println!("  sockets: {}", resp.get("sockets").unwrap().to_string());
    let profile = resp.get("profile").expect("profiled reply carries profile");
    println!("  profile: {}", profile.to_string());
    assert!(profile.get("phases").and_then(|p| p.as_arr()).is_some());

    // Non-torus topologies over the wire: the "topology" field swaps the
    // distance model under the same geometric pipeline. A fat-tree prices
    // hops as 2 x (levels above the nearest common ancestor); a dragonfly
    // prices minimal local-global-local routes with a configurable global
    // premium. Both go through the hier (node-level) mapper.
    let chain_edges = r#"[[0,1],[1,2],[2,3],[3,4],[4,5],[5,6],[6,7]]"#;
    let ft_req = Json::parse(&format!(
        r#"{{"op":"map",
            "tcoords":[[0],[1],[2],[3],[4],[5],[6],[7]],
            "pcoords":[[0],[1],[2],[3]],
            "edges":{chain_edges},
            "hier":{{"ranks_per_node":2}},
            "topology":{{"fattree":{{"levels":2,"radix":2}}}}}}"#
    ))
    .expect("static request parses");
    let resp = client.request(&ft_req).expect("fat-tree map request");
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    assert_eq!(resp.get("topology").and_then(|t| t.as_str()), Some("fattree"));
    println!("\nfat-tree (levels 2, radix 2) mapping over the wire:");
    println!("  map: {}", resp.get("map").unwrap().to_string());

    let df_req = Json::parse(&format!(
        r#"{{"op":"map",
            "tcoords":[[0],[1],[2],[3],[4],[5],[6],[7]],
            "pcoords":[[0,0],[0,1],[1,0],[1,1]],
            "edges":{chain_edges},
            "hier":{{"ranks_per_node":2}},
            "topology":{{"dragonfly":{{"groups":2,"routers_per_group":2}}}}}}"#
    ))
    .expect("static request parses");
    let resp = client.request(&df_req).expect("dragonfly map request");
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    assert_eq!(resp.get("topology").and_then(|t| t.as_str()), Some("dragonfly"));
    println!("dragonfly (2 groups x 2 routers) mapping over the wire:");
    println!("  map: {}", resp.get("map").unwrap().to_string());

    // The trace endpoint: recent span trees (non-empty whenever a
    // profiled request ran or the global recorder is on) plus the metrics
    // registry snapshot.
    let trace = client
        .request(&Json::parse(r#"{"op":"trace"}"#).unwrap())
        .expect("trace request");
    assert_eq!(trace.get("ok"), Some(&Json::Bool(true)), "{trace:?}");
    let traces = trace.get("traces").and_then(|t| t.as_arr()).expect("traces array");
    println!("\ntrace endpoint: {} recent trace(s)", traces.len());
    // The global ring only collects spans while the recorder is on
    // (TASKMAP_TRACE) — a plain demo run legitimately sees an empty
    // forest here.
    if trace.get("enabled") == Some(&Json::Bool(true)) {
        assert!(!traces.is_empty(), "recorder on but no span tree in the ring");
    }

    // The retrying client: reconnects and backs off on transient errors
    // (overloaded / shutting_down), honoring the server's retry_after_ms
    // hint. A healthy server answers on the first attempt.
    let pong = request_with_retry(
        svc.addr,
        &Json::parse(r#"{"op":"ping"}"#).unwrap(),
        &RetryPolicy::default(),
    )
    .expect("ping with retry");
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));

    // Service telemetry: identity, counters, per-op latency quantiles,
    // and the pool view.
    let stats = client
        .request(&Json::parse(r#"{"op":"stats"}"#).unwrap())
        .expect("stats request");
    println!("\nservice stats:");
    for key in ["version", "uptime_s", "accepted", "completed", "shed", "panics"] {
        println!("  {key:>9}: {}", stats.get(key).unwrap().to_string());
    }
    if let Some(map_op) = stats.get("ops").and_then(|o| o.get("map")) {
        println!(
            "  map op:    p50 {}us / p95 {}us / p99 {}us over {} request(s)",
            map_op.get("p50_us").unwrap().to_string(),
            map_op.get("p95_us").unwrap().to_string(),
            map_op.get("p99_us").unwrap().to_string(),
            map_op.get("count").unwrap().to_string(),
        );
    }
    println!("  pool:      {}", stats.get("pool").unwrap().to_string());
    println!("shutting down (graceful drain).");
    svc.stop();
}
