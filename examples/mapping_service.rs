//! Mapping service demo: start the TCP mapping daemon, connect as a
//! client, and request a mapping over the wire — the deployment shape where
//! a job launcher asks a central service for rank placements.
//!
//! ```bash
//! cargo run --release --example mapping_service            # demo mode
//! cargo run --release --example mapping_service -- --serve # daemon mode
//! ```

use taskmap::coordinator::service::{Client, Service};
use taskmap::sfc::PartOrdering;

fn main() {
    let serve_only = std::env::args().any(|a| a == "--serve");
    let svc = Service::start("127.0.0.1:0").expect("bind");
    println!("mapping service on {}", svc.addr);
    if serve_only {
        println!("daemon mode; Ctrl-C to stop");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    // Demo: a 4x4 task grid onto a reversed 4x4 processor grid.
    let mut client = Client::connect(svc.addr).expect("connect");
    let tasks: Vec<Vec<f64>> = (0..16)
        .map(|i| vec![(i % 4) as f64, (i / 4) as f64])
        .collect();
    let procs: Vec<Vec<f64>> = (0..16)
        .map(|i| vec![(3 - i % 4) as f64, (3 - i / 4) as f64])
        .collect();
    let mapping = client
        .map(&tasks, &procs, PartOrdering::FZ)
        .expect("map request");
    println!("\ntask -> rank (geometric FZ mapping over the wire):");
    for (t, r) in mapping.iter().enumerate() {
        print!("{t:>3}->{r:<3}");
        if t % 4 == 3 {
            println!();
        }
    }
    // Sanity: bijection.
    let mut s = mapping.clone();
    s.sort_unstable();
    assert_eq!(s, (0..16).collect::<Vec<u32>>());
    println!("\nbijection verified; shutting down.");
    svc.stop();
}
