//! Quickstart: map a 3D stencil application onto a sparse Cray XK7
//! allocation and compare the geometric mapping against the default rank
//! order.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use taskmap::apps::stencil::stencil_graph;
use taskmap::machine::{cray_xk7, SparseAllocator};
use taskmap::mapping::pipeline::{z2_map, Z2Config};
use taskmap::mapping::rotations::NativeBackend;
use taskmap::metrics::eval_full;
use taskmap::simulate::{comm_time, CommModel};

fn main() {
    // 1. The application: an 8x8x8 task grid, 7-point stencil, 1 MB faces.
    let graph = stencil_graph(&[8, 8, 8], false, 1.0e6);
    println!(
        "application: {} tasks, {} edges, {:.1} MB total per exchange",
        graph.num_tasks,
        graph.edges.len(),
        graph.total_volume() / 1e6
    );

    // 2. The machine: an 8x8x8 Gemini torus, 2 nodes per router, 16 cores
    //    per node, 35% occupied by other jobs. Ask ALPS for 32 nodes.
    let allocator = SparseAllocator {
        machine: cray_xk7(&[8, 8, 8]),
        nodes_per_router: 2,
        ranks_per_node: 16,
        occupancy: 0.35,
    };
    let alloc = allocator.allocate(512 / 16, 42);
    println!(
        "allocation: {} nodes / {} ranks on a {:?} torus",
        alloc.num_nodes(),
        alloc.num_ranks(),
        alloc.torus.sizes
    );

    // 3. Map: default (task i -> rank i) vs the geometric Z2 mapper.
    let default: Vec<u32> = (0..graph.num_tasks as u32).collect();
    let z2 = z2_map(&graph, &graph.coords, &alloc, &Z2Config::z2_1(), &NativeBackend);

    // 4. Compare metrics (Section 3) and simulated communication time.
    let model = CommModel::default();
    println!("\n{:<22} {:>12} {:>12}", "metric", "default", "Z2 (geometric)");
    let md = eval_full(&graph, &default, &alloc);
    let mz = eval_full(&graph, &z2, &alloc);
    println!("{:<22} {:>12.2} {:>12.2}", "AverageHops", md.avg_hops, mz.avg_hops);
    println!(
        "{:<22} {:>12.3e} {:>12.3e}",
        "WeightedHops", md.weighted_hops, mz.weighted_hops
    );
    let (ld, lz) = (md.link.unwrap(), mz.link.unwrap());
    println!("{:<22} {:>12.3e} {:>12.3e}", "Data(M) bytes", ld.max_data, lz.max_data);
    println!(
        "{:<22} {:>12.3e} {:>12.3e}",
        "Latency(M)", ld.max_latency, lz.max_latency
    );
    let td = comm_time(&graph, &default, &alloc, &model);
    let tz = comm_time(&graph, &z2, &alloc, &model);
    println!("{:<22} {:>12.4} {:>12.4}", "comm time (s)", td.total, tz.total);
    println!(
        "\ngeometric mapping reduces simulated communication time by {:.0}%",
        (1.0 - tz.total / td.total) * 100.0
    );
}
