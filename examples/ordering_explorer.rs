//! Ordering explorer: interactively reproduce Table 1 cells — map a
//! td-dimensional stencil onto a pd-dimensional torus with each SFC
//! ordering and report AverageHops.
//!
//! ```bash
//! cargo run --release --example ordering_explorer -- --td 2 --pd 3 --log2 12
//! cargo run --release --example ordering_explorer -- --small   # quick sweep
//! ```

use taskmap::coordinator::table1::{average_hops_cell, Connectivity};
use taskmap::sfc::PartOrdering;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |flag: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    if args.iter().any(|a| a == "--small") || args.is_empty() {
        // A quick sweep over interesting (td, pd) shapes.
        println!(
            "{:>4} {:>4} {:>8} | {:>8} {:>8} {:>8} {:>8}",
            "td", "pd", "tasks", "H", "Z", "FZ", "MFZ"
        );
        for (td, pd) in [(1, 2), (2, 1), (2, 3), (3, 2), (2, 4), (3, 3), (1, 5)] {
            let l = lcm(td, pd).max(10).next_multiple_of(lcm(td, pd));
            let n = 1usize << l;
            print!("{td:>4} {pd:>4} {n:>8} |");
            for o in [
                PartOrdering::Hilbert,
                PartOrdering::Z,
                PartOrdering::FZ,
                PartOrdering::MFZ,
            ] {
                let v = average_hops_cell(n, pd, td, Connectivity::MeshToTorus, o);
                print!(" {v:>8.2}");
            }
            println!();
        }
        println!("\n(MeshToTorus connectivity; MFZ uses task-side lower-half flips)");
        return;
    }
    let td = get("--td", 2);
    let pd = get("--pd", 3);
    let l = get("--log2", 12) as u32;
    let n = 1usize << l;
    println!("mapping a {td}D stencil of {n} tasks onto a {pd}D block of {n} nodes\n");
    println!("{:>14} {:>10} {:>10} {:>10}", "connectivity", "ordering", "AvgHops", "vs best");
    for conn in Connectivity::ALL {
        let mut results = Vec::new();
        for o in [
            PartOrdering::Hilbert,
            PartOrdering::Z,
            PartOrdering::FZ,
            PartOrdering::MFZ,
        ] {
            results.push((o, average_hops_cell(n, pd, td, conn, o)));
        }
        let best = results
            .iter()
            .map(|(_, v)| *v)
            .fold(f64::INFINITY, f64::min);
        for (o, v) in results {
            println!(
                "{:>14} {:>10} {:>10.2} {:>10.2}",
                conn.name(),
                o.name(),
                v,
                v / best
            );
        }
    }
}

fn lcm(a: usize, b: usize) -> usize {
    let gcd = |mut a: usize, mut b: usize| {
        while b != 0 {
            (a, b) = (b, a % b);
        }
        a
    };
    a / gcd(a, b) * b
}
